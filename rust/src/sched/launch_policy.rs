//! The [`LaunchPolicy`] trait — the open extension seam for launch-order
//! selection — plus every built-in implementation.
//!
//! The paper contributes one policy (Algorithm 1) and evaluates it against
//! FIFO / reverse / random baselines. Related systems explore the same
//! design space with different selectors (Kernelet's greedy co-schedule
//! pairing, ACS's dynamic-graph scheduling), so the coordinator, CLI,
//! benches and experiment harness all dispatch through this trait: a new
//! policy is one `impl` plus one registry line, with no changes anywhere
//! else.

use super::algorithm::reorder_with;
use super::score::{CombinedProfile, ScoreConfig};
use crate::gpu::{GpuSpec, KernelProfile};
use crate::util::SplitMix64;

/// How to choose a launch order for a batch of kernels.
///
/// Implementations must return a permutation of `0..kernels.len()`
/// (every index exactly once). `Send + Sync` so one policy instance can be
/// shared across the coordinator's per-device worker threads.
pub trait LaunchPolicy: Send + Sync {
    /// The policy's registry spelling (e.g. `"fifo"`, `"random:42"`),
    /// which [`crate::sched::registry::parse`] accepts back — or, for
    /// configurations the registry cannot express (e.g. bespoke ablation
    /// `ScoreConfig`s), a distinct label that never impersonates a
    /// registry spelling.
    fn name(&self) -> String;

    /// Produce a launch order: a permutation of `0..kernels.len()`.
    fn order(&self, gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize>;
}

/// Submission order (what a CUDA app does by default).
#[derive(Debug, Clone, Copy, Default)]
pub struct FifoPolicy;

impl LaunchPolicy for FifoPolicy {
    fn name(&self) -> String {
        "fifo".into()
    }

    fn order(&self, _gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
        (0..kernels.len()).collect()
    }
}

/// Reversed submission order (a simple adversarial baseline).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReversePolicy;

impl LaunchPolicy for ReversePolicy {
    fn name(&self) -> String {
        "reverse".into()
    }

    fn order(&self, _gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
        (0..kernels.len()).rev().collect()
    }
}

/// A uniformly random permutation from a fixed seed (the paper's "random
/// order choice" comparison). Deterministic per seed.
#[derive(Debug, Clone, Copy)]
pub struct RandomPolicy {
    pub seed: u64,
}

impl RandomPolicy {
    pub fn new(seed: u64) -> Self {
        RandomPolicy { seed }
    }
}

impl LaunchPolicy for RandomPolicy {
    fn name(&self) -> String {
        format!("random:{}", self.seed)
    }

    fn order(&self, _gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..kernels.len()).collect();
        SplitMix64::new(self.seed).shuffle(&mut order);
        order
    }
}

/// The paper's Algorithm 1 (greedy round construction), with a
/// configurable [`ScoreConfig`] for the ablation studies.
#[derive(Debug, Clone, Copy)]
pub struct Algorithm1Policy {
    pub cfg: ScoreConfig,
}

impl Algorithm1Policy {
    /// The default (tuned) configuration.
    pub fn new() -> Self {
        Algorithm1Policy {
            cfg: ScoreConfig::default(),
        }
    }

    /// Algorithm 1 exactly as printed in the paper.
    pub fn strict() -> Self {
        Algorithm1Policy {
            cfg: ScoreConfig::paper_strict(),
        }
    }

    pub fn with_config(cfg: ScoreConfig) -> Self {
        Algorithm1Policy { cfg }
    }
}

impl Default for Algorithm1Policy {
    fn default() -> Self {
        Algorithm1Policy::new()
    }
}

impl LaunchPolicy for Algorithm1Policy {
    fn name(&self) -> String {
        // The two registry spellings round-trip through the registry;
        // bespoke ScoreConfigs (ablation studies) are labelled distinctly
        // so logs and batch reports never pass them off as the default.
        if self.cfg == ScoreConfig::default() {
            "algorithm1".into()
        } else if self.cfg == ScoreConfig::paper_strict() {
            "algorithm1:strict".into()
        } else {
            "algorithm1:custom".into()
        }
    }

    fn order(&self, gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
        reorder_with(gpu, kernels, &self.cfg).order
    }
}

/// Shortest-job-first by estimated total work (`N_tblk · work_per_block`).
///
/// A classic serving baseline: small kernels drain first, which minimizes
/// mean *completion* time but ignores resource packing entirely — exactly
/// the blind spot the paper's Algorithm 1 exists to fix, which makes SJF a
/// useful foil in the policy comparison.
#[derive(Debug, Clone, Copy, Default)]
pub struct SjfPolicy;

impl LaunchPolicy for SjfPolicy {
    fn name(&self) -> String {
        "sjf".into()
    }

    fn order(&self, _gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..kernels.len()).collect();
        idx.sort_by(|&a, &b| {
            kernels[a]
                .total_work()
                .partial_cmp(&kernels[b].total_work())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx
    }
}

/// Kernelet-style greedy co-schedule (Zhong & He): repeatedly emit the
/// *pair* of remaining kernels whose work-weighted combined
/// instructions/bytes ratio lands closest to the GPU's balanced ratio
/// `R_B`, among pairs that fit together in one execution round.
///
/// Unlike Algorithm 1 this never grows a round past two kernels and scores
/// only the compute/memory balance (no resource-leftover terms) — it is
/// the "co-schedule two complementary slices" heuristic transplanted to
/// whole-kernel launch ordering. Within each pair the heavier
/// shared-memory kernel launches first (same release-early argument as the
/// paper's intra-round rule); kernels that pair with nothing are emitted
/// in submission order.
#[derive(Debug, Clone, Copy, Default)]
pub struct GreedyCoschedulePolicy;

impl LaunchPolicy for GreedyCoschedulePolicy {
    fn name(&self) -> String {
        "coschedule".into()
    }

    fn order(&self, gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
        let profiles: Vec<CombinedProfile> =
            kernels.iter().map(|k| CombinedProfile::of(gpu, k)).collect();
        let mut remaining: Vec<usize> = (0..kernels.len()).collect();
        let mut order = Vec::with_capacity(kernels.len());

        while remaining.len() >= 2 {
            // Best-pairing pass: positions into `remaining` plus the
            // distance |R_comb - R_B| (lower is better).
            let mut best: Option<(usize, usize, f64)> = None;
            for i in 0..remaining.len() {
                for j in (i + 1)..remaining.len() {
                    let (a, b) = (remaining[i], remaining[j]);
                    if !profiles[a].fits_with(gpu, &profiles[b]) {
                        continue;
                    }
                    let rc = profiles[a].combine(&profiles[b]).ratio();
                    let d = if rc.is_finite() {
                        (rc - gpu.balanced_ratio).abs()
                    } else {
                        f64::MAX
                    };
                    match best {
                        None => best = Some((i, j, d)),
                        Some((_, _, bd)) if d < bd => best = Some((i, j, d)),
                        _ => {}
                    }
                }
            }
            match best {
                Some((i, j, _)) => {
                    let (a, b) = (remaining[i], remaining[j]);
                    // Remove the higher position first to keep `i` valid.
                    remaining.remove(j);
                    remaining.remove(i);
                    if kernels[b].shmem_per_block > kernels[a].shmem_per_block {
                        order.push(b);
                        order.push(a);
                    } else {
                        order.push(a);
                        order.push(b);
                    }
                }
                // No two remaining kernels fit together: emit FIFO-stable.
                None => order.push(remaining.remove(0)),
            }
        }
        order.append(&mut remaining);
        order
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::kernel;
    use super::*;

    fn gpu() -> GpuSpec {
        GpuSpec::gtx580()
    }

    fn ks() -> Vec<KernelProfile> {
        (0..6)
            .map(|i| kernel(&format!("k{i}"), 16, 4 + (i % 3) * 8, 0, 1.0 + i as f64))
            .collect()
    }

    fn assert_perm(order: &[usize], n: usize) {
        let mut s: Vec<usize> = order.to_vec();
        s.sort_unstable();
        assert_eq!(s, (0..n).collect::<Vec<_>>(), "not a permutation");
    }

    #[test]
    fn builtin_policies_emit_permutations() {
        let g = gpu();
        let ks = ks();
        let policies: Vec<Box<dyn LaunchPolicy>> = vec![
            Box::new(FifoPolicy),
            Box::new(ReversePolicy),
            Box::new(RandomPolicy::new(7)),
            Box::new(Algorithm1Policy::new()),
            Box::new(Algorithm1Policy::strict()),
            Box::new(SjfPolicy),
            Box::new(GreedyCoschedulePolicy),
        ];
        for p in &policies {
            assert_perm(&p.order(&g, &ks), ks.len());
        }
    }

    #[test]
    fn trait_fifo_matches_identity() {
        assert_eq!(FifoPolicy.order(&gpu(), &ks()), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(ReversePolicy.order(&gpu(), &ks()), vec![5, 4, 3, 2, 1, 0]);
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let g = gpu();
        let ks = ks();
        assert_eq!(
            RandomPolicy::new(3).order(&g, &ks),
            RandomPolicy::new(3).order(&g, &ks)
        );
        assert_ne!(
            RandomPolicy::new(3).order(&g, &ks),
            RandomPolicy::new(4).order(&g, &ks)
        );
    }

    #[test]
    fn sjf_orders_by_total_work() {
        let g = gpu();
        // kernel() fixes work_per_block = 100, so total work is driven by
        // the grid size alone; scramble it so SJF cannot be identity by
        // accident.
        let mut ks: Vec<KernelProfile> = (0..4)
            .map(|i| kernel(&format!("k{i}"), 16, 4, 0, 2.0 + i as f64))
            .collect();
        ks[0].n_blocks = 64;
        ks[1].n_blocks = 16;
        ks[2].n_blocks = 48;
        ks[3].n_blocks = 32;
        assert_eq!(SjfPolicy.order(&g, &ks), vec![1, 3, 2, 0]);
    }

    #[test]
    fn sjf_is_stable_on_ties() {
        let g = gpu();
        let ks: Vec<KernelProfile> =
            (0..4).map(|i| kernel(&format!("k{i}"), 16, 4, 0, 2.0)).collect();
        assert_eq!(SjfPolicy.order(&g, &ks), vec![0, 1, 2, 3]);
    }

    #[test]
    fn coschedule_pairs_opposing_types() {
        let g = gpu();
        // Two memory-bound (R=1) and two compute-bound (R=40) kernels:
        // each emitted pair must mix the types (combined ratio closest to
        // R_B comes from opposite sides).
        let ks = vec![
            kernel("m1", 16, 24, 0, 1.0),
            kernel("m2", 16, 24, 0, 1.0),
            kernel("c1", 16, 24, 0, 40.0),
            kernel("c2", 16, 24, 0, 40.0),
        ];
        let order = GreedyCoschedulePolicy.order(&g, &ks);
        assert_perm(&order, 4);
        for pair in order.chunks(2) {
            let mixed = (ks[pair[0]].ratio < g.balanced_ratio)
                != (ks[pair[1]].ratio < g.balanced_ratio);
            assert!(mixed, "pair {pair:?} not mixed in {order:?}");
        }
    }

    #[test]
    fn coschedule_puts_heavier_shmem_first_in_pair() {
        let g = gpu();
        let ks = vec![
            kernel("light", 16, 4, 8 * 1024, 1.0),
            kernel("heavy", 16, 4, 24 * 1024, 40.0),
        ];
        assert_eq!(GreedyCoschedulePolicy.order(&g, &ks), vec![1, 0]);
    }

    #[test]
    fn coschedule_handles_unpairable_kernels() {
        let g = gpu();
        // Each kernel alone exhausts SM warps: no pair fits, FIFO emitted.
        let ks = vec![
            kernel("a", 16, 48, 0, 3.0),
            kernel("b", 16, 48, 0, 5.0),
            kernel("c", 16, 48, 0, 7.0),
        ];
        assert_eq!(GreedyCoschedulePolicy.order(&g, &ks), vec![0, 1, 2]);
    }

    #[test]
    fn names_are_registry_spellings() {
        assert_eq!(FifoPolicy.name(), "fifo");
        assert_eq!(ReversePolicy.name(), "reverse");
        assert_eq!(RandomPolicy::new(42).name(), "random:42");
        assert_eq!(Algorithm1Policy::new().name(), "algorithm1");
        assert_eq!(Algorithm1Policy::strict().name(), "algorithm1:strict");
        let custom = Algorithm1Policy::with_config(ScoreConfig {
            resource_balance: false,
            ..ScoreConfig::default()
        });
        assert_eq!(custom.name(), "algorithm1:custom");
        assert_eq!(SjfPolicy.name(), "sjf");
        assert_eq!(GreedyCoschedulePolicy.name(), "coschedule");
    }
}
