//! ScoreGen and ProfileCombine — lines 15–28 of Algorithm 1.

use crate::gpu::{GpuSpec, KernelProfile, ResourceVec};

/// Which score terms are active. All on by default; the ablation bench
/// (DESIGN.md A1) toggles them individually.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScoreConfig {
    /// Lines 18–20: normalized leftover of shmem / registers / warps.
    pub resource_balance: bool,
    /// Lines 21–22: the `R_comb` vs `R_B` balance term.
    pub ratio_balance: bool,
    /// Line 21's gate: only add the ratio term when the two profiles sit on
    /// opposite sides of `R_B` (compute-bound vs memory-bound).
    pub opposing_gate: bool,
    /// Sort round members by decreasing shared-memory usage (the paper's
    /// intra-round order rule: "kernels with more N_shm finish faster and
    /// release N_shm sooner").
    pub shm_sort: bool,
    /// How the constructed rounds are sequenced in the final launch order
    /// (ablation A2b).
    pub round_order: RoundOrder,
}

/// Across-round sequencing policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundOrder {
    /// Construction order `Rd_0, Rd_1, …` — the paper as written.
    Construction,
    /// Heaviest shared-memory round first.
    ShmDesc,
    /// Longest estimated round first (LPT). The paper profiles
    /// `N_inst_i` (Table 1) and argues within a round that kernels which
    /// finish sooner should release resources sooner; LPT across rounds
    /// is the same argument at round granularity: launching long rounds
    /// first lets the short, resource-light rounds back-fill the
    /// stragglers' SM slots instead of extending the makespan tail.
    DurationDesc,
}

impl Default for ScoreConfig {
    fn default() -> Self {
        ScoreConfig {
            resource_balance: true,
            ratio_balance: true,
            opposing_gate: true,
            shm_sort: true,
            round_order: RoundOrder::DurationDesc,
        }
    }
}

impl ScoreConfig {
    /// Algorithm 1 exactly as printed in the paper (rounds emitted in
    /// construction order).
    pub fn paper_strict() -> Self {
        ScoreConfig {
            round_order: RoundOrder::Construction,
            ..ScoreConfig::default()
        }
    }
}

/// ProfileCombine's *virtual kernel*: the aggregate profile of one or more
/// kernels, carried as per-SM footprint plus total work and memory traffic
/// (so `R_comb` is work-weighted exactly as in the paper:
/// `R_comb(a,b) = (inst_a + inst_b) / (mem_a + mem_b)`).
#[derive(Debug, Clone, PartialEq)]
pub struct CombinedProfile {
    /// Summed per-SM footprint (`N_shm`, `N_reg`, `N_warp`, blocks).
    pub footprint: ResourceVec,
    /// Total compute work (instruction units) across all grids.
    pub work: f64,
    /// Total memory traffic (bytes) across all grids.
    pub mem: f64,
}

impl CombinedProfile {
    /// Profile of a single kernel.
    pub fn of(gpu: &GpuSpec, k: &KernelProfile) -> Self {
        CombinedProfile {
            footprint: k.per_sm_footprint(gpu),
            work: k.total_work(),
            mem: k.total_mem(),
        }
    }

    /// ProfileCombine: merge two profiles into one virtual kernel.
    pub fn combine(&self, other: &CombinedProfile) -> CombinedProfile {
        CombinedProfile {
            footprint: self.footprint + other.footprint,
            work: self.work + other.work,
            mem: self.mem + other.mem,
        }
    }

    /// Instructions/bytes ratio of the virtual kernel.
    pub fn ratio(&self) -> f64 {
        if self.mem <= 0.0 {
            f64::INFINITY
        } else {
            self.work / self.mem
        }
    }

    /// Do `self` and `other` fit together within one execution round?
    pub fn fits_with(&self, gpu: &GpuSpec, other: &CombinedProfile) -> bool {
        (self.footprint + other.footprint).fits_within(&gpu.sm_capacity())
    }
}

/// ScoreGen for one pair of (possibly virtual) kernel profiles.
///
/// Returns 0 when the pair cannot share an execution round (line 17).
/// Otherwise sums the normalized leftover of shared memory, registers and
/// warps (lines 18–20) and, when the profiles are of opposing type
/// (`R_i ≤ R_B ≤ R_j` or vice versa, line 21), a term rewarding a combined
/// ratio close to `R_B` (line 22).
pub fn score(
    gpu: &GpuSpec,
    a: &CombinedProfile,
    b: &CombinedProfile,
    cfg: &ScoreConfig,
) -> f64 {
    if !a.fits_with(gpu, b) {
        return 0.0;
    }
    let cap = gpu.sm_capacity();
    let mut s = 0.0;

    if cfg.resource_balance {
        let left_shm = (cap.shmem - a.footprint.shmem - b.footprint.shmem) / cap.shmem;
        let left_reg = (cap.regs - a.footprint.regs - b.footprint.regs) / cap.regs;
        let left_warp = (cap.warps - a.footprint.warps - b.footprint.warps) / cap.warps;
        s += left_shm.max(0.0) + left_reg.max(0.0) + left_warp.max(0.0);
    }

    if cfg.ratio_balance {
        let rb = gpu.balanced_ratio;
        let (ra, rbb) = (a.ratio(), b.ratio());
        let opposing = (ra <= rb && rb <= rbb) || (rbb <= rb && rb <= ra);
        if opposing || !cfg.opposing_gate {
            let comb = a.combine(b);
            let rc = comb.ratio();
            if rc.is_finite() {
                s += (1.0 - (rc - rb).abs() / rb).max(0.0);
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::super::tests::kernel;
    use super::*;

    fn gpu() -> GpuSpec {
        GpuSpec::gtx580()
    }

    fn prof(k: &KernelProfile) -> CombinedProfile {
        CombinedProfile::of(&gpu(), k)
    }

    #[test]
    fn combine_is_commutative_and_sums() {
        let a = prof(&kernel("a", 16, 4, 8192, 2.0));
        let b = prof(&kernel("b", 32, 8, 4096, 8.0));
        let ab = a.combine(&b);
        let ba = b.combine(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.footprint.warps, a.footprint.warps + b.footprint.warps);
        assert_eq!(ab.work, a.work + b.work);
    }

    #[test]
    fn combined_ratio_is_work_weighted() {
        // Equal work, R 2 and 8 -> mem W/2 + W/8 -> R_comb = 3.2.
        let a = prof(&kernel("a", 16, 4, 0, 2.0));
        let b = prof(&kernel("b", 16, 4, 0, 8.0));
        assert!((a.combine(&b).ratio() - 3.2).abs() < 1e-12);
    }

    #[test]
    fn non_fitting_pair_scores_zero() {
        let a = prof(&kernel("a", 16, 32, 0, 3.0));
        let b = prof(&kernel("b", 16, 32, 0, 5.0)); // 64 warps > 48
        assert_eq!(score(&gpu(), &a, &b, &ScoreConfig::default()), 0.0);
    }

    #[test]
    fn lighter_pairs_score_higher() {
        let cfg = ScoreConfig::default();
        let small = prof(&kernel("s", 16, 4, 4096, 3.0));
        let big = prof(&kernel("b", 16, 16, 16384, 3.0));
        let other = prof(&kernel("o", 16, 4, 4096, 3.0));
        assert!(score(&gpu(), &small, &other, &cfg) > score(&gpu(), &big, &other, &cfg));
    }

    #[test]
    fn opposing_types_get_ratio_bonus() {
        let cfg = ScoreConfig::default();
        // mem (R=1) + cmp (R=8): opposing, R_comb near R_B scores extra.
        let mem = prof(&kernel("m", 16, 4, 0, 1.0));
        let cmp = prof(&kernel("c", 16, 4, 0, 8.0));
        let mem2 = prof(&kernel("m2", 16, 4, 0, 1.0));
        assert!(score(&gpu(), &mem, &cmp, &cfg) > score(&gpu(), &mem, &mem2, &cfg));
    }

    #[test]
    fn same_side_pairs_get_no_ratio_bonus() {
        let g = gpu();
        let cfg = ScoreConfig::default();
        let no_ratio = ScoreConfig {
            ratio_balance: false,
            ..cfg
        };
        // Both memory-bound: ratio term must not fire.
        let a = prof(&kernel("a", 16, 4, 0, 1.0));
        let b = prof(&kernel("b", 16, 4, 0, 2.0));
        assert_eq!(score(&g, &a, &b, &cfg), score(&g, &a, &b, &no_ratio));
    }

    #[test]
    fn opposing_gate_off_always_adds_ratio_term() {
        let g = gpu();
        let cfg = ScoreConfig {
            opposing_gate: false,
            ..ScoreConfig::default()
        };
        let a = prof(&kernel("a", 16, 4, 0, 3.0));
        let b = prof(&kernel("b", 16, 4, 0, 3.5));
        // Same side of R_B, but gate off: score includes a ratio term.
        let with_gate = score(&g, &a, &b, &ScoreConfig::default());
        let without = score(&g, &a, &b, &cfg);
        assert!(without > with_gate);
    }

    #[test]
    fn ratio_term_peaks_at_rb() {
        let g = gpu();
        let cfg = ScoreConfig {
            resource_balance: false,
            ..ScoreConfig::default()
        };
        // Pair straddling R_B with combined exactly R_B scores the full 1.0.
        // work a = work b, R_a = 2.74, R_b chosen so R_comb = R_B = 4.11:
        // 2W / (W/ra + W/rbb) = 4.11 -> 1/ra + 1/rbb = 2/4.11.
        let ra = 2.74f64;
        let rbb = 1.0 / (2.0 / 4.11 - 1.0 / ra);
        let a = prof(&kernel("a", 16, 4, 0, ra));
        let b = prof(&kernel("b", 16, 4, 0, rbb));
        let s = score(&g, &a, &b, &cfg);
        assert!((s - 1.0).abs() < 1e-9, "{s}");
    }

    #[test]
    fn score_is_symmetric() {
        let g = gpu();
        let cfg = ScoreConfig::default();
        let a = prof(&kernel("a", 16, 8, 8192, 2.0));
        let b = prof(&kernel("b", 32, 4, 4096, 9.0));
        assert_eq!(score(&g, &a, &b, &cfg), score(&g, &b, &a, &cfg));
    }
}
