//! Algorithm 1 — the greedy concurrent-kernel launch-order algorithm.
//!
//! ```text
//! while K != ∅:
//!     (K_a, K_b) = argmax ScoreMatrix over K×K          # open round r
//!     push K_a, K_b into Rd_r sorted by decreasing N_shm; remove from K
//!     K_comb = ProfileCombine(K_a, K_b)
//!     while ∃ kernels in K that fit within Rd_r:
//!         K_c = argmax ScoreGen(K_comb, ·)
//!         push K_c into Rd_r (keep shm-descending order); remove from K
//!         K_comb = ProfileCombine(K_comb, K_c)
//! output: concatenation Rd_0, Rd_1, …
//! ```

use super::score::{score, CombinedProfile, ScoreConfig};
use crate::gpu::{GpuSpec, KernelProfile};

/// Output of Algorithm 1: the launch order and its round structure.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// Kernel indices in the derived launch order.
    pub order: Vec<usize>,
    /// The same order, split into the execution rounds the algorithm
    /// constructed (`Rd_0`, `Rd_1`, …).
    pub rounds: Vec<Vec<usize>>,
}

/// Run Algorithm 1 with the default score configuration.
pub fn reorder(gpu: &GpuSpec, kernels: &[KernelProfile]) -> Schedule {
    reorder_with(gpu, kernels, &ScoreConfig::default())
}

/// Run Algorithm 1 with an explicit [`ScoreConfig`] (ablation hook).
pub fn reorder_with(gpu: &GpuSpec, kernels: &[KernelProfile], cfg: &ScoreConfig) -> Schedule {
    let profiles: Vec<CombinedProfile> =
        kernels.iter().map(|k| CombinedProfile::of(gpu, k)).collect();
    let mut remaining: Vec<usize> = (0..kernels.len()).collect();
    let mut rounds: Vec<Vec<usize>> = Vec::new();

    while !remaining.is_empty() {
        if remaining.len() == 1 {
            rounds.push(vec![remaining.pop().unwrap()]);
            break;
        }

        // --- open the round with the best-scoring pair ---
        let mut best: Option<(usize, usize, f64)> = None; // positions in `remaining`
        for i in 0..remaining.len() {
            for j in (i + 1)..remaining.len() {
                let (a, b) = (remaining[i], remaining[j]);
                if !profiles[a].fits_with(gpu, &profiles[b]) {
                    continue;
                }
                let s = score(gpu, &profiles[a], &profiles[b], cfg);
                match best {
                    None => best = Some((i, j, s)),
                    Some((_, _, bs)) if s > bs => best = Some((i, j, s)),
                    _ => {}
                }
            }
        }

        let mut round: Vec<usize>;
        let mut comb: CombinedProfile;
        match best {
            None => {
                // No pair fits together: this round is a single kernel.
                // (Paper scope note: when every kernel fills an SM alone,
                // ordering is immaterial; we emit FIFO-stable singles.)
                let k = remaining.remove(0);
                rounds.push(vec![k]);
                continue;
            }
            Some((i, j, _)) => {
                let (a, b) = (remaining[i], remaining[j]);
                // Remove higher position first to keep indices valid.
                remaining.remove(j);
                remaining.remove(i);
                round = vec![a, b];
                comb = profiles[a].combine(&profiles[b]);
            }
        }

        // --- grow the round greedily ---
        loop {
            let mut best_c: Option<(usize, f64)> = None; // position in `remaining`
            for (pos, &c) in remaining.iter().enumerate() {
                if !comb.fits_with(gpu, &profiles[c]) {
                    continue;
                }
                let s = score(gpu, &comb, &profiles[c], cfg);
                match best_c {
                    None => best_c = Some((pos, s)),
                    Some((_, bs)) if s > bs => best_c = Some((pos, s)),
                    _ => {}
                }
            }
            let Some((pos, _)) = best_c else { break };
            let c = remaining.remove(pos);
            comb = comb.combine(&profiles[c]);
            round.push(c);
        }

        // --- intra-round order: decreasing shared-memory usage ---
        // "this allows kernels with more N_shm to finish faster, and thus
        // release N_shm sooner". Stable sort keeps insertion order on ties.
        if cfg.shm_sort {
            round.sort_by(|&x, &y| {
                profiles[y]
                    .footprint
                    .shmem
                    .partial_cmp(&profiles[x].footprint.shmem)
                    .unwrap()
            });
        }
        rounds.push(round);
    }

    // Across-round sequencing (see RoundOrder). Stable sorts keep the
    // construction order on ties.
    match cfg.round_order {
        super::score::RoundOrder::Construction => {}
        super::score::RoundOrder::ShmDesc => {
            rounds.sort_by(|a, b| {
                let shm =
                    |r: &Vec<usize>| -> f64 { r.iter().map(|&k| profiles[k].footprint.shmem).sum() };
                shm(b).partial_cmp(&shm(a)).unwrap()
            });
        }
        super::score::RoundOrder::DurationDesc => {
            let dur = |r: &Vec<usize>| -> f64 {
                let round_warps: f64 = r.iter().map(|&k| profiles[k].footprint.warps).sum();
                r.iter()
                    .map(|&k| estimate_duration(gpu, &kernels[k], round_warps))
                    .fold(0.0, f64::max)
            };
            rounds.sort_by(|a, b| dur(b).partial_cmp(&dur(a)).unwrap());
        }
    }

    let order: Vec<usize> = rounds.iter().flatten().copied().collect();
    Schedule { order, rounds }
}

/// Estimated duration of kernel `k` inside a round whose SMs hold
/// `round_warps` resident warps: all of the kernel's blocks are
/// co-resident, each progressing at the processor-sharing compute rate
/// `C · w_b / max(round_warps, warps_to_saturate)`.
fn estimate_duration(gpu: &GpuSpec, k: &KernelProfile, round_warps: f64) -> f64 {
    let denom = round_warps.max(gpu.warps_to_saturate as f64);
    let rate = gpu.compute_rate_per_sm * k.warps_per_block as f64 / denom;
    k.work_per_block / rate
}

#[cfg(test)]
mod tests {
    use super::super::tests::kernel;
    use super::*;

    fn gpu() -> GpuSpec {
        GpuSpec::gtx580()
    }

    fn assert_is_permutation(order: &[usize], n: usize) {
        let mut seen = vec![false; n];
        for &i in order {
            assert!(i < n && !seen[i], "bad order {order:?}");
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s), "incomplete order {order:?}");
    }

    #[test]
    fn output_is_a_permutation() {
        let ks: Vec<_> = (0..8)
            .map(|i| kernel(&format!("k{i}"), 16, 4 + (i % 4) * 8, (i as u32 % 3) * 8192, 1.0 + i as f64))
            .collect();
        let s = reorder(&gpu(), &ks);
        assert_is_permutation(&s.order, ks.len());
        // Rounds partition the order.
        let flat: Vec<usize> = s.rounds.iter().flatten().copied().collect();
        assert_eq!(flat, s.order);
    }

    #[test]
    fn single_kernel() {
        let ks = vec![kernel("k", 16, 4, 0, 3.0)];
        let s = reorder(&gpu(), &ks);
        assert_eq!(s.order, vec![0]);
        assert_eq!(s.rounds, vec![vec![0]]);
    }

    #[test]
    fn two_kernels_that_fit_share_a_round() {
        let ks = vec![kernel("a", 16, 4, 0, 2.0), kernel("b", 16, 4, 0, 8.0)];
        let s = reorder(&gpu(), &ks);
        assert_eq!(s.rounds.len(), 1);
        assert_eq!(s.rounds[0].len(), 2);
    }

    #[test]
    fn pairs_opposing_ratio_types() {
        // 2 memory-bound + 2 compute-bound, warps sized two-per-round:
        // each round must contain one of each type.
        let ks = vec![
            kernel("m1", 16, 24, 0, 1.0),
            kernel("m2", 16, 24, 0, 1.0),
            kernel("c1", 16, 24, 0, 40.0),
            kernel("c2", 16, 24, 0, 40.0),
        ];
        let s = reorder(&gpu(), &ks);
        assert_eq!(s.rounds.len(), 2);
        for r in &s.rounds {
            let has_mem = r.iter().any(|&i| ks[i].ratio < 4.11);
            let has_cmp = r.iter().any(|&i| ks[i].ratio > 4.11);
            assert!(has_mem && has_cmp, "round {r:?} not mixed");
        }
    }

    #[test]
    fn round_members_sorted_by_shm_desc() {
        let ks = vec![
            kernel("a", 16, 4, 8 * 1024, 3.0),
            kernel("b", 16, 4, 24 * 1024, 3.0),
            kernel("c", 16, 4, 16 * 1024, 3.0),
        ];
        let s = reorder(&gpu(), &ks);
        assert_eq!(s.rounds.len(), 1);
        let shms: Vec<u32> = s.rounds[0]
            .iter()
            .map(|&i| ks[i].shmem_per_block)
            .collect();
        assert_eq!(shms, vec![24 * 1024, 16 * 1024, 8 * 1024]);
    }

    #[test]
    fn shm_sort_can_be_disabled() {
        let ks = vec![
            kernel("a", 16, 4, 8 * 1024, 3.0),
            kernel("b", 16, 4, 24 * 1024, 3.0),
        ];
        let cfg = ScoreConfig {
            shm_sort: false,
            ..ScoreConfig::default()
        };
        let s = reorder_with(&gpu(), &ks, &cfg);
        assert_is_permutation(&s.order, 2);
    }

    #[test]
    fn sm_filling_kernels_get_single_rounds() {
        // Each kernel alone exhausts SM warps: no pair ever fits.
        let ks = vec![
            kernel("a", 16, 48, 0, 3.0),
            kernel("b", 16, 48, 0, 5.0),
            kernel("c", 16, 48, 0, 7.0),
        ];
        let s = reorder(&gpu(), &ks);
        assert_eq!(s.rounds.len(), 3);
        for r in &s.rounds {
            assert_eq!(r.len(), 1);
        }
    }

    #[test]
    fn rounds_respect_capacity() {
        use crate::sim::rounds::fits_in_round;
        let ks: Vec<_> = (0..10)
            .map(|i| {
                kernel(
                    &format!("k{i}"),
                    16,
                    4 + (i % 5) * 4,
                    ((i % 4) as u32) * 8192,
                    1.0 + (i as f64) * 1.3,
                )
            })
            .collect();
        let s = reorder(&gpu(), &ks);
        for round in &s.rounds {
            let mut used = crate::gpu::ResourceVec::ZERO;
            for &k in round {
                assert!(
                    fits_in_round(&gpu(), &ks, &used, k),
                    "round {round:?} violates capacity"
                );
                used += ks[k].per_sm_footprint(&gpu());
            }
        }
    }

    #[test]
    fn deterministic() {
        let ks: Vec<_> = (0..8)
            .map(|i| kernel(&format!("k{i}"), 16, 4 + (i % 4) * 8, 0, 1.0 + i as f64))
            .collect();
        assert_eq!(reorder(&gpu(), &ks), reorder(&gpu(), &ks));
    }
}
