//! String registry for launch policies — the single place that maps CLI /
//! config spellings onto [`LaunchPolicy`] trait objects.
//!
//! Every spelling the seed CLI accepted keeps working (`fifo`, `reverse`,
//! `random:<seed>`, `algorithm1` with its `algorithm` / `alg` aliases),
//! plus the policies added with the trait redesign (`sjf`, `coschedule`,
//! `algorithm1:strict`) and the budgeted search delegate
//! (`search[:<strategy>[:<evals>]]`, backed by [`crate::search`]).
//! Unknown spellings return a [`PolicyParseError`] whose message lists
//! every valid name, so the CLI can fail helpfully.
//!
//! [`parse`], [`all_policies`] and [`help_table`] all derive from the one
//! [`REGISTRY`] table below, so adding a policy really is one `impl` plus
//! one table row — the three views cannot drift.

use super::launch_policy::{
    Algorithm1Policy, FifoPolicy, GreedyCoschedulePolicy, LaunchPolicy, RandomPolicy,
    ReversePolicy, SjfPolicy,
};

/// One registered policy: canonical spelling, accepted aliases, a
/// description, and the constructor. `random:<seed>` is the only
/// parameterized spelling and is handled by [`parse`] directly (its
/// constructor here uses seed 0, for [`all_policies`]).
pub struct RegistryEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub description: &'static str,
    make: fn() -> Box<dyn LaunchPolicy>,
}

/// The policy registry — the single source of truth for spellings.
pub static REGISTRY: &[RegistryEntry] = &[
    RegistryEntry {
        name: "fifo",
        aliases: &[],
        description: "submission (arrival) order — the CUDA default",
        make: || Box::new(FifoPolicy),
    },
    RegistryEntry {
        name: "reverse",
        aliases: &[],
        description: "reversed submission order (adversarial baseline)",
        make: || Box::new(ReversePolicy),
    },
    RegistryEntry {
        name: "random:<seed>",
        aliases: &[],
        description: "seeded uniform-random permutation (the paper's random-choice reference)",
        make: || Box::new(RandomPolicy::new(0)),
    },
    RegistryEntry {
        name: "algorithm1",
        aliases: &["algorithm", "alg"],
        description: "the paper's greedy round-construction scheduler (Algorithm 1)",
        make: || Box::new(Algorithm1Policy::new()),
    },
    RegistryEntry {
        name: "algorithm1:strict",
        aliases: &[],
        description: "Algorithm 1 exactly as printed (rounds in construction order)",
        make: || Box::new(Algorithm1Policy::strict()),
    },
    RegistryEntry {
        name: "sjf",
        aliases: &[],
        description: "shortest-job-first by estimated total work (packing-blind baseline)",
        make: || Box::new(SjfPolicy),
    },
    RegistryEntry {
        name: "coschedule",
        aliases: &["greedy-coschedule", "kernelet"],
        description: "Kernelet-style greedy pairing by combined-ratio distance to R_B",
        make: || Box::new(GreedyCoschedulePolicy),
    },
    RegistryEntry {
        name: "search",
        aliases: &[],
        description: "budgeted launch-order search: exact branch-and-bound for small windows, \
                      anytime metaheuristics beyond (search[:<strategy>[:<evals>]], e.g. \
                      search:anneal:7:5000 — see `crate::search`)",
        make: || Box::new(crate::search::SearchPolicy::new()),
    },
];

/// Error returned for unknown policy spellings; its `Display` lists every
/// valid name.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PolicyParseError {
    pub input: String,
}

impl std::fmt::Display for PolicyParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let names: Vec<&str> = REGISTRY.iter().map(|e| e.name).collect();
        write!(
            f,
            "unknown policy `{}` — valid policies: {}",
            self.input,
            names.join(", ")
        )
    }
}

impl std::error::Error for PolicyParseError {}

/// Parse a policy spelling into a trait object.
///
/// ```
/// let p = kreorder::sched::registry::parse("random:42").unwrap();
/// assert_eq!(p.name(), "random:42");
/// assert!(kreorder::sched::registry::parse("nope").is_err());
/// ```
pub fn parse(s: &str) -> Result<Box<dyn LaunchPolicy>, PolicyParseError> {
    let lower = s.to_ascii_lowercase();
    if let Some(seed) = lower.strip_prefix("random:") {
        return seed
            .parse()
            .ok()
            .map(|seed| Box::new(RandomPolicy::new(seed)) as Box<dyn LaunchPolicy>)
            .ok_or_else(|| PolicyParseError { input: s.into() });
    }
    if let Some(rest) = lower.strip_prefix("search:") {
        // `search:<strategy>[:<evals>]`: the whole remainder is tried as
        // a strategy spelling first (strategies carry their own `:<seed>`
        // parameter), then with the last `:`-segment as an eval budget —
        // so `search:anneal:7` is strategy `anneal:7` at the default
        // budget and `search:anneal:7:5000` caps it at 5000 evaluations.
        // Only *anytime* strategies are accepted here: a budget-capped
        // parallel branch-and-bound is not run-to-run deterministic, and
        // a launch policy must be (small windows still get exact bnb
        // automatically, where the budget provably covers the tree).
        use crate::search::{parse_strategy, SearchPolicy, DEFAULT_POLICY_EVALS};
        // The *canonical* strategy spelling is stored (e.g. bare
        // `local` → `local:0`, alias `sa:5` → `anneal:5`) so that
        // `name()` — `search:<strategy>:<evals>` — reparses to the same
        // policy instead of misreading a seedless spelling's budget as
        // a seed.
        let anytime = |sp: &str| {
            parse_strategy(sp)
                .ok()
                .map(|st| st.name())
                .filter(|name| name != "bnb")
        };
        if let Some(canonical) = anytime(rest) {
            return Ok(Box::new(SearchPolicy::with(canonical, DEFAULT_POLICY_EVALS)));
        }
        if let Some((strat, evals)) = rest.rsplit_once(':') {
            if let (Some(canonical), Ok(evals)) = (anytime(strat), evals.parse::<u64>()) {
                return Ok(Box::new(SearchPolicy::with(canonical, evals)));
            }
        }
        return Err(PolicyParseError { input: s.into() });
    }
    REGISTRY
        .iter()
        .find(|e| e.name == lower || e.aliases.contains(&lower.as_str()))
        .map(|e| (e.make)())
        .ok_or_else(|| PolicyParseError { input: s.into() })
}

/// One representative instance of every registered policy (seeded
/// policies use seed 0) — what property tests and the `sched` subcommand
/// iterate over.
pub fn all_policies() -> Vec<Box<dyn LaunchPolicy>> {
    REGISTRY.iter().map(|e| (e.make)()).collect()
}

/// Human-readable registry table (one line per policy, with aliases).
pub fn help_table() -> String {
    let mut out = String::new();
    for e in REGISTRY {
        let alias_note = if e.aliases.is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", e.aliases.join(", "))
        };
        out.push_str(&format!("  {:<20} {}{alias_note}\n", e.name, e.description));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;
    use crate::workloads::synthetic_workload;

    #[test]
    fn every_seed_spelling_still_parses() {
        for s in ["fifo", "reverse", "algorithm", "algorithm1", "alg", "random:42"] {
            assert!(parse(s).is_ok(), "{s}");
        }
    }

    #[test]
    fn new_policies_parse() {
        for s in [
            "sjf",
            "coschedule",
            "greedy-coschedule",
            "kernelet",
            "algorithm1:strict",
        ] {
            assert!(parse(s).is_ok(), "{s}");
        }
    }

    #[test]
    fn search_spellings_parse() {
        // Bare, with an anytime strategy (strategies carry their own
        // `:<seed>`), and with a trailing eval budget.
        for s in [
            "search",
            "search:anneal:7",
            "search:local:0",
            "search:anneal:7:5000",
            "search:local:0:256",
        ] {
            let p = parse(s).unwrap_or_else(|e| panic!("{e}"));
            assert!(p.name().starts_with("search:"), "{s} -> {}", p.name());
        }
        assert_eq!(parse("search:anneal:7:5000").unwrap().name(), "search:anneal:7:5000");
        // Strategy without an explicit budget gets the default.
        assert_eq!(
            parse("search:anneal:7").unwrap().name(),
            format!("search:anneal:7:{}", crate::search::DEFAULT_POLICY_EVALS)
        );
        // Seedless and alias spellings canonicalize, so every emitted
        // name reparses to the *same* policy (a raw "search:local" name
        // would otherwise read its budget suffix back as a seed).
        let p = parse("search:local").unwrap();
        assert_eq!(
            p.name(),
            format!("search:local:0:{}", crate::search::DEFAULT_POLICY_EVALS)
        );
        assert_eq!(parse(&p.name()).unwrap().name(), p.name());
        assert_eq!(
            parse("search:sa:5").unwrap().name(),
            format!("search:anneal:5:{}", crate::search::DEFAULT_POLICY_EVALS)
        );
        // Unknown strategies, malformed budgets, and bnb (which is not
        // anytime — a budget-capped parallel exact solve is not
        // deterministic, so a policy may not request it) are rejected.
        for s in [
            "search:nope",
            "search:anneal:x:y",
            "search:",
            "search:bnb",
            "search:bnb:100",
        ] {
            assert!(parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn parse_is_case_insensitive() {
        assert_eq!(parse("FIFO").unwrap().name(), "fifo");
        assert_eq!(parse("Random:7").unwrap().name(), "random:7");
    }

    /// Every table row's canonical spelling and every alias must parse,
    /// and parse to the same behaviour as the row's constructor — the
    /// anti-drift guarantee.
    #[test]
    fn every_registry_row_parses_to_its_constructor() {
        let gpu = GpuSpec::gtx580();
        let ks = synthetic_workload(&gpu, 6, 4);
        for e in REGISTRY {
            let reference = (e.make)();
            let spelling = e.name.replace("<seed>", "0");
            let mut spellings = vec![spelling];
            spellings.extend(e.aliases.iter().map(|a| a.to_string()));
            for s in spellings {
                let p = parse(&s).unwrap_or_else(|err| panic!("{err}"));
                assert_eq!(
                    p.order(&gpu, &ks),
                    reference.order(&gpu, &ks),
                    "spelling {s}"
                );
            }
        }
    }

    #[test]
    fn names_round_trip_through_parse() {
        for p in all_policies() {
            let name = p.name();
            let reparsed = parse(&name).unwrap_or_else(|e| panic!("{e}"));
            // Same spelling and same behaviour on a probe workload.
            assert_eq!(reparsed.name(), name);
            let gpu = GpuSpec::gtx580();
            let ks = synthetic_workload(&gpu, 6, 9);
            assert_eq!(reparsed.order(&gpu, &ks), p.order(&gpu, &ks), "{name}");
        }
    }

    #[test]
    fn bad_input_error_lists_valid_names() {
        let err = parse("nope").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("nope"));
        for name in ["fifo", "reverse", "algorithm1", "sjf", "coschedule"] {
            assert!(msg.contains(name), "missing {name} in: {msg}");
        }
        assert!(parse("random:x").is_err());
        assert!(parse("random:").is_err());
    }

    #[test]
    fn help_table_covers_registry() {
        let t = help_table();
        for e in REGISTRY {
            assert!(t.contains(e.name));
        }
    }
}
