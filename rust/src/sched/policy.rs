//! Launch-order policies: the baselines the paper's evaluation compares
//! against, plus Algorithm 1 behind the same interface (used by the
//! coordinator and the experiment harness).

use super::algorithm::reorder;
use crate::gpu::{GpuSpec, KernelProfile};
use crate::util::SplitMix64;

/// How to choose a launch order for a batch of kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Submission order (what a CUDA app does by default).
    Fifo,
    /// Reversed submission order (a simple adversarial baseline).
    Reverse,
    /// A uniformly random permutation from the given seed (the paper's
    /// "random order choice" comparison).
    Random(u64),
    /// The paper's Algorithm 1.
    Algorithm1,
}

impl Policy {
    /// Produce a launch order (a permutation of `0..kernels.len()`).
    pub fn order(&self, gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
        let n = kernels.len();
        match self {
            Policy::Fifo => (0..n).collect(),
            Policy::Reverse => (0..n).rev().collect(),
            Policy::Random(seed) => {
                let mut order: Vec<usize> = (0..n).collect();
                SplitMix64::new(*seed).shuffle(&mut order);
                order
            }
            Policy::Algorithm1 => reorder(gpu, kernels).order,
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(Policy::Fifo),
            "reverse" => Some(Policy::Reverse),
            "algorithm" | "algorithm1" | "alg" => Some(Policy::Algorithm1),
            other => other
                .strip_prefix("random:")
                .and_then(|seed| seed.parse().ok().map(Policy::Random)),
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Policy::Fifo => write!(f, "fifo"),
            Policy::Reverse => write!(f, "reverse"),
            Policy::Random(s) => write!(f, "random:{s}"),
            Policy::Algorithm1 => write!(f, "algorithm1"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::kernel;
    use super::*;

    fn ks() -> Vec<KernelProfile> {
        (0..6)
            .map(|i| kernel(&format!("k{i}"), 16, 4 + (i % 3) * 8, 0, 1.0 + i as f64))
            .collect()
    }

    fn assert_perm(order: &[usize], n: usize) {
        let mut s: Vec<usize> = order.to_vec();
        s.sort_unstable();
        assert_eq!(s, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn fifo_is_identity() {
        let gpu = GpuSpec::gtx580();
        assert_eq!(Policy::Fifo.order(&gpu, &ks()), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn reverse_reverses() {
        let gpu = GpuSpec::gtx580();
        assert_eq!(Policy::Reverse.order(&gpu, &ks()), vec![5, 4, 3, 2, 1, 0]);
    }

    #[test]
    fn random_is_seeded_permutation() {
        let gpu = GpuSpec::gtx580();
        let a = Policy::Random(7).order(&gpu, &ks());
        let b = Policy::Random(7).order(&gpu, &ks());
        let c = Policy::Random(8).order(&gpu, &ks());
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_perm(&a, 6);
        assert_perm(&c, 6);
    }

    #[test]
    fn algorithm_produces_permutation() {
        let gpu = GpuSpec::gtx580();
        assert_perm(&Policy::Algorithm1.order(&gpu, &ks()), 6);
    }

    #[test]
    fn parse_roundtrip() {
        for p in [
            Policy::Fifo,
            Policy::Reverse,
            Policy::Random(42),
            Policy::Algorithm1,
        ] {
            assert_eq!(Policy::parse(&p.to_string()), Some(p));
        }
        assert_eq!(Policy::parse("nope"), None);
        assert_eq!(Policy::parse("random:x"), None);
    }
}
