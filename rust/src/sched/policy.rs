//! Deprecated closed-enum policy selection, kept one release as a
//! migration shim for the open [`LaunchPolicy`] trait
//! (`sched::launch_policy`) and the string registry (`sched::registry`).

#![allow(deprecated)]

use super::launch_policy::{
    Algorithm1Policy, FifoPolicy, LaunchPolicy, RandomPolicy, ReversePolicy,
};
use crate::gpu::{GpuSpec, KernelProfile};

/// How to choose a launch order for a batch of kernels.
#[deprecated(
    since = "0.2.0",
    note = "use `sched::registry::parse` or a `sched::LaunchPolicy` implementation; \
            this closed enum cannot express out-of-tree policies"
)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Submission order (what a CUDA app does by default).
    Fifo,
    /// Reversed submission order (a simple adversarial baseline).
    Reverse,
    /// A uniformly random permutation from the given seed (the paper's
    /// "random order choice" comparison).
    Random(u64),
    /// The paper's Algorithm 1.
    Algorithm1,
}

impl Policy {
    /// Produce a launch order (a permutation of `0..kernels.len()`).
    ///
    /// Kept as the original direct implementation (no boxing) so the
    /// `policy_overhead` bench compares the genuine pre-redesign path
    /// against trait-object dispatch.
    pub fn order(&self, gpu: &GpuSpec, kernels: &[KernelProfile]) -> Vec<usize> {
        let n = kernels.len();
        match self {
            Policy::Fifo => (0..n).collect(),
            Policy::Reverse => (0..n).rev().collect(),
            Policy::Random(seed) => {
                let mut order: Vec<usize> = (0..n).collect();
                crate::util::SplitMix64::new(*seed).shuffle(&mut order);
                order
            }
            Policy::Algorithm1 => super::algorithm::reorder(gpu, kernels).order,
        }
    }

    /// Parse from a CLI string. Prefer [`super::registry::parse`], which
    /// knows the full registry and reports helpful errors.
    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(Policy::Fifo),
            "reverse" => Some(Policy::Reverse),
            "algorithm" | "algorithm1" | "alg" => Some(Policy::Algorithm1),
            other => other
                .strip_prefix("random:")
                .and_then(|seed| seed.parse().ok().map(Policy::Random)),
        }
    }

    /// Bridge into the trait world: the equivalent [`LaunchPolicy`].
    pub fn to_launch_policy(&self) -> Box<dyn LaunchPolicy> {
        match self {
            Policy::Fifo => Box::new(FifoPolicy),
            Policy::Reverse => Box::new(ReversePolicy),
            Policy::Random(seed) => Box::new(RandomPolicy::new(*seed)),
            Policy::Algorithm1 => Box::new(Algorithm1Policy::new()),
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Policy::Fifo => write!(f, "fifo"),
            Policy::Reverse => write!(f, "reverse"),
            Policy::Random(s) => write!(f, "random:{s}"),
            Policy::Algorithm1 => write!(f, "algorithm1"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::kernel;
    use super::*;

    fn ks() -> Vec<KernelProfile> {
        (0..6)
            .map(|i| kernel(&format!("k{i}"), 16, 4 + (i % 3) * 8, 0, 1.0 + i as f64))
            .collect()
    }

    fn assert_perm(order: &[usize], n: usize) {
        let mut s: Vec<usize> = order.to_vec();
        s.sort_unstable();
        assert_eq!(s, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn fifo_is_identity() {
        let gpu = GpuSpec::gtx580();
        assert_eq!(Policy::Fifo.order(&gpu, &ks()), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn reverse_reverses() {
        let gpu = GpuSpec::gtx580();
        assert_eq!(Policy::Reverse.order(&gpu, &ks()), vec![5, 4, 3, 2, 1, 0]);
    }

    #[test]
    fn random_is_seeded_permutation() {
        let gpu = GpuSpec::gtx580();
        let a = Policy::Random(7).order(&gpu, &ks());
        let b = Policy::Random(7).order(&gpu, &ks());
        let c = Policy::Random(8).order(&gpu, &ks());
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_perm(&a, 6);
        assert_perm(&c, 6);
    }

    #[test]
    fn algorithm_produces_permutation() {
        let gpu = GpuSpec::gtx580();
        assert_perm(&Policy::Algorithm1.order(&gpu, &ks()), 6);
    }

    #[test]
    fn parse_roundtrip() {
        for p in [
            Policy::Fifo,
            Policy::Reverse,
            Policy::Random(42),
            Policy::Algorithm1,
        ] {
            assert_eq!(Policy::parse(&p.to_string()), Some(p));
        }
        assert_eq!(Policy::parse("nope"), None);
        assert_eq!(Policy::parse("random:x"), None);
    }

    #[test]
    fn enum_shim_matches_trait_policies() {
        // The shim must stay behaviour-identical to the trait impls it
        // bridges to, for every workload shape.
        let gpu = GpuSpec::gtx580();
        let ks = ks();
        for p in [
            Policy::Fifo,
            Policy::Reverse,
            Policy::Random(11),
            Policy::Algorithm1,
        ] {
            let via_enum = p.order(&gpu, &ks);
            let via_trait = super::super::registry::parse(&p.to_string())
                .unwrap()
                .order(&gpu, &ks);
            assert_eq!(via_enum, via_trait, "{p}");
        }
    }
}
