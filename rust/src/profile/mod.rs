//! Artifact profile loading — the consumer side of the "CUDA profiler"
//! stand-in. `python/compile/aot.py` runs XLA HLO cost analysis on every
//! lowered kernel variant and emits `artifacts/profiles.json`; this module
//! parses it (with the in-tree JSON parser) and exposes per-variant
//! instruction/byte profiles, which the serving path uses to derive `R_i`
//! for kernels that are not in the paper's tables.

use crate::util::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// The whole `profiles.json` manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: u32,
    pub variants: BTreeMap<String, VariantEntry>,
}

/// One AOT-compiled kernel variant.
#[derive(Debug, Clone)]
pub struct VariantEntry {
    pub app: String,
    pub description: String,
    /// HLO text filename, relative to the artifacts directory.
    pub hlo: String,
    pub inputs: Vec<InputSpec>,
    pub profile: CostProfile,
}

/// Shape/dtype of one runtime input (kept in sync with
/// `python/compile/model.py` input conventions).
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl InputSpec {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// XLA cost-analysis quantities for one variant — the stand-in for the
/// paper's `N_inst_i` and memory-transaction counts.
#[derive(Debug, Clone)]
pub struct CostProfile {
    pub flops: f64,
    pub transcendentals: f64,
    pub bytes_accessed: f64,
    pub instructions: f64,
    /// `R_i` = instructions / bytes accessed.
    pub ratio: f64,
}

/// A loaded artifacts directory.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl ArtifactStore {
    /// Load `profiles.json` from `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("profiles.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} (run `make artifacts` first)",
                manifest_path.display()
            )
        })?;
        let manifest = parse_manifest(&text).context("parsing profiles.json")?;
        Ok(ArtifactStore { dir, manifest })
    }

    /// Default artifacts location: `$KREORDER_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("KREORDER_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Variant metadata by name.
    pub fn variant(&self, name: &str) -> Result<&VariantEntry> {
        self.manifest
            .variants
            .get(name)
            .with_context(|| format!("unknown artifact variant `{name}`"))
    }

    /// Absolute path of a variant's HLO text file.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.variant(name)?.hlo))
    }

    /// All variant names, sorted (deterministic iteration for reports).
    pub fn variant_names(&self) -> Vec<String> {
        self.manifest.variants.keys().cloned().collect()
    }
}

fn parse_manifest(text: &str) -> Result<Manifest> {
    let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let format = field_f64(&doc, "format")? as u32;
    anyhow::ensure!(format == 1, "unsupported manifest format {format}");
    let mut variants = BTreeMap::new();
    let vmap = doc
        .get("variants")
        .and_then(Json::as_obj)
        .context("missing `variants` object")?;
    for (name, v) in vmap {
        variants.insert(name.clone(), parse_variant(v).with_context(|| name.clone())?);
    }
    Ok(Manifest { format, variants })
}

fn parse_variant(v: &Json) -> Result<VariantEntry> {
    let inputs = v
        .get("inputs")
        .and_then(Json::as_arr)
        .context("missing `inputs`")?
        .iter()
        .map(parse_input)
        .collect::<Result<Vec<_>>>()?;
    let p = v.get("profile").context("missing `profile`")?;
    Ok(VariantEntry {
        app: field_str(v, "app")?,
        description: field_str(v, "description").unwrap_or_default(),
        hlo: field_str(v, "hlo")?,
        inputs,
        profile: CostProfile {
            flops: field_f64(p, "flops")?,
            transcendentals: field_f64(p, "transcendentals").unwrap_or(0.0),
            bytes_accessed: field_f64(p, "bytes_accessed")?,
            instructions: field_f64(p, "instructions")?,
            ratio: field_f64(p, "ratio")?,
        },
    })
}

fn parse_input(v: &Json) -> Result<InputSpec> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .context("input missing `shape`")?
        .iter()
        .map(|d| d.as_f64().map(|x| x as usize).context("bad dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok(InputSpec {
        shape,
        dtype: field_str(v, "dtype")?,
    })
}

fn field_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing numeric field `{key}`"))
}

fn field_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("missing string field `{key}`"))?
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": 1,
        "variants": {
            "ep_16k": {
                "app": "ep",
                "description": "EP tally",
                "hlo": "ep_16k.hlo.txt",
                "inputs": [{"shape": [16384], "dtype": "uint32"}],
                "profile": {
                    "flops": 1000.0,
                    "transcendentals": 10.0,
                    "bytes_accessed": 500.0,
                    "instructions": 1040.0,
                    "ratio": 2.08
                }
            }
        }
    }"#;

    fn store_in(name: &str, body: &str) -> Result<ArtifactStore> {
        let dir = std::env::temp_dir().join(format!("kreorder_profile_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("profiles.json"), body).unwrap();
        ArtifactStore::load(&dir)
    }

    #[test]
    fn parses_manifest() {
        let s = store_in("t1", SAMPLE).unwrap();
        let v = s.variant("ep_16k").unwrap();
        assert_eq!(v.app, "ep");
        assert_eq!(v.inputs[0].numel(), 16384);
        assert_eq!(v.inputs[0].dtype, "uint32");
        assert!((v.profile.ratio - 2.08).abs() < 1e-12);
        assert!((v.profile.instructions - 1040.0).abs() < 1e-12);
    }

    #[test]
    fn hlo_path_joins_dir() {
        let s = store_in("t2", SAMPLE).unwrap();
        assert!(s
            .hlo_path("ep_16k")
            .unwrap()
            .ends_with("ep_16k.hlo.txt"));
        assert!(s.hlo_path("nope").is_err());
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(ArtifactStore::load("/definitely/not/here").is_err());
    }

    #[test]
    fn bad_format_rejected() {
        let bad = SAMPLE.replace("\"format\": 1", "\"format\": 99");
        assert!(store_in("t3", &bad).is_err());
    }

    #[test]
    fn missing_profile_field_rejected() {
        let bad = SAMPLE.replace("\"flops\": 1000.0,", "");
        assert!(store_in("t4", &bad).is_err());
    }

    #[test]
    fn variant_names_sorted() {
        let two = SAMPLE.replace(
            "\"ep_16k\": {",
            "\"zz\": {\"app\":\"ep\",\"hlo\":\"z.hlo.txt\",\"inputs\":[],
              \"profile\":{\"flops\":1,\"bytes_accessed\":1,\"instructions\":1,\"ratio\":1}},
             \"ep_16k\": {",
        );
        let s = store_in("t5", &two).unwrap();
        assert_eq!(s.variant_names(), vec!["ep_16k".to_string(), "zz".to_string()]);
    }
}
