//! `kreorder` — CLI for the kernel-launch-reordering reproduction.
//!
//! Subcommands (see `kreorder help`):
//!
//! * `table3`  — regenerate the paper's Table 3 (all six experiments).
//! * `fig1`    — regenerate Fig. 1 (EpBsEsSw-8 ranking + distribution CSVs).
//! * `sweep`   — permutation sweep of one experiment.
//! * `search`  — branch-and-bound / anytime launch-order search (n ≫ 12).
//! * `sched`   — show every registered policy's order/rounds for a workload.
//! * `serve`   — run the launch-coordinator service (simulated or real PJRT payloads).
//! * `fleet`   — multi-device online scheduling: routed arrivals over a GPU fleet.
//! * `fault`   — fleet run under a deterministic fault plan (crashes, stragglers,
//!   launch failures) with seeded retry and health-aware rerouting.
//! * `trace`   — inspect a recorded `--trace` artifact (JSONL event stream or
//!   Chrome trace-event JSON).
//! * `ablate`  — score-component ablation across experiments.
//! * `policies`— list the launch-policy registry.
//! * `artifacts` — list AOT artifacts and their measured profiles.
//!
//! Every subcommand dispatches ordering through `sched::LaunchPolicy` and
//! timing through `exec::ExecutionBackend` trait objects, so registry
//! additions show up here with no CLI changes.

use anyhow::{bail, Context, Result};
use kreorder::coordinator::{CoordinatorBuilder, LaunchRequest};
use kreorder::exec::{self, ExecutionBackend};
use kreorder::gpu::GpuSpec;
use kreorder::metrics::{ExperimentRow, Histogram, Table3};
use kreorder::obs::TraceSink;
use kreorder::perm::sweep_with;
use kreorder::profile::ArtifactStore;
use kreorder::sched::{registry, reorder, reorder_with, ScoreConfig};
use kreorder::sim;
use kreorder::util::SplitMix64;
use kreorder::workloads::{all_experiments, by_id, synthetic_workload};
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "table3" => cmd_table3(rest),
        "fig1" => cmd_fig1(rest),
        "sweep" => cmd_sweep(rest),
        "search" => cmd_search(rest),
        "sched" => cmd_sched(rest),
        "serve" => cmd_serve(rest),
        "fleet" => cmd_fleet(rest),
        "fault" => cmd_fault(rest),
        "trace" => cmd_trace(rest),
        "ablate" => cmd_ablate(rest),
        "list" => cmd_list(rest),
        "policies" => cmd_policies(rest),
        "artifacts" => cmd_artifacts(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command `{other}` (try `kreorder help`)"),
    }
}

fn print_help() {
    println!(
        "kreorder — Reordering GPU Kernel Launches (Li, Narayana, El-Ghazawi 2015)

USAGE: kreorder <COMMAND> [OPTIONS]

COMMANDS:
  table3 [--exp ID] [--csv FILE] [--backend B]
                                       reproduce Table 3 (default: all experiments)
  fig1 [--out-dir DIR] [--bins N]      reproduce Fig. 1 for EpBsEsSw-8
  sweep --exp ID [--backend B]         permutation-space stats for one experiment
  search (--exp ID | --synthetic N | --scenario FAMILY:N) [--seed S]
         [--deps SPEC-OR-FILE] [--strategy STRAT] [--budget EVALS] [--backend B]
         [--trajectory] [--trace FILE[:FMT]] [--trace-sample K]
         [--compare-sweep] [--compare-eval] [--list]
                                       launch-order search beyond the factorial wall;
                                       FAMILY may be a DAG family (chain, fanout, fanin,
                                       layered, mlinfer) and --deps adds precedence
                                       edges (`0->2;1->2` or a kreorder-deps CSV file):
                                       search is then over topological orders only
                                       (--compare-eval re-runs on the full-evaluation /
                                       no-symmetry reference path: prints both evals/s
                                       and verifies bit-identical incumbents)
  sched (--exp ID | --synthetic N [--seed S]) [--backend B]
                                       show every registered policy's order vs makespan
  serve [--batches N] [--window K] [--policy P] [--devices D] [--seed S]
        [--artifacts DIR] [--sim-only] [--backend B]
                                       run the launch coordinator service
  serve --arrivals PROC [--count N] [--scenario FAMILY] [--window WP]
        [--strategy S|fifo] [--budget EVALS] [--deps SPEC-OR-FILE]
        [--decision-cost MS] [--slo MS] [--admission P] [--oracle]
        [--record FILE] [--trace FILE[:FMT]] [--backend B]
                                       ONLINE mode: deterministic virtual-clock run of
                                       the streaming scheduler (arrivals PROC = e.g.
                                       poisson:<rate>:<seed>; window WP = e.g.
                                       linger:8:50; see `kreorder serve --list-online`;
                                       admission P = none|bound:<q>|deadline:<slo_ms>|
                                       codel:<target_ms>:<interval_ms> sheds arrivals
                                       at the door under overload)
  fleet [--devices SPEC] [--route POLICY] [--count N] [--scenario FAMILY]
        [--arrivals PROC] [--window WP] [--strategy S|fifo] [--budget EVALS]
        [--decision-cost MS] [--admission P] [--backend B] [--record FILE]
        [--replay FILE] [--trace FILE[:FMT]] [--compare-roundrobin] [--oracle]
                                       multi-device online scheduling: arrivals routed
                                       over a (possibly heterogeneous) fleet, each
                                       device its own reorder window (--devices SPEC =
                                       e.g. 4 or 1,1,0.5; see `kreorder fleet
                                       --list-routes`)
  fault (--plan SPEC-OR-FILE | --gen-faults N) [--fault-seed S] [--horizon MS]
        [--retries N] [--devices SPEC] [--route POLICY] [--count N]
        [--scenario FAMILY] [--arrivals PROC] [--window WP] [--strategy S|fifo]
        [--budget EVALS] [--decision-cost MS] [--admission P] [--backend B]
        [--trace FILE[:FMT]] [--compare-nofault] [--list-faults]
                                       fleet run under a deterministic fault plan:
                                       device crashes/recoveries, slowdowns, seeded
                                       launch failures with retry + backoff
                                       (see `kreorder fault --list-faults`)
  trace inspect FILE                   summarize a recorded trace artifact: JSONL
                                       event streams fold into the counters snapshot,
                                       Chrome trace-event JSON is validated and its
                                       lane/span summary printed
  ablate [--exp ID] [--backend B]      score-component ablation
  list [--kind K]                      list every string registry (policy, strategy,
                                       route, window, arrivals, fault-plan, admission,
                                       trace) or one kind;
                                       consolidates the per-command --list flags, which
                                       remain as aliases
  policies                             list the launch-policy registry
  artifacts [--dir DIR]                list AOT artifacts + measured profiles

EXPERIMENT IDS: ep-6-shm ep-6-grid bs-6-blk epbs-6 epbs-6-shm epbsessw-8
POLICIES: fifo reverse random:<seed> algorithm1 algorithm1:strict sjf coschedule
          search[:<strategy>[:<evals>]]   (see `kreorder policies`)
STRATEGIES & SCENARIOS: `kreorder search --list`
ARRIVALS & WINDOW POLICIES: `kreorder serve --list-online`
ROUTE POLICIES & DEVICE SPECS: `kreorder fleet --list-routes`
FAULT PLANS: `kreorder fault --list-faults`
ADMISSION POLICIES: `kreorder list --kind admission`
TRACE SINKS: `kreorder list --kind trace`; --trace FILE writes a JSONL event
          stream, --trace FILE:chrome a Chrome/Perfetto timeline JSON
BACKENDS: sim (fluid simulator, default), analytic (round model){}",
        if cfg!(feature = "pjrt") {
            ", pjrt (serve only)"
        } else {
            "; pjrt needs --features pjrt"
        }
    );
}

/// Tiny flag parser: `--key value` pairs plus boolean flags.
fn opt<'a>(args: &'a [String], key: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn flag(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

/// The model backend selected by `--backend` (default: fluid simulator).
fn model_backend(args: &[String]) -> Result<Box<dyn ExecutionBackend>> {
    let name = opt(args, "--backend").unwrap_or("sim");
    exec::parse_model_backend(name).map_err(anyhow::Error::from)
}

/// Same selection as a factory, for the permutation sweeps (one backend
/// per sweep worker). Ensures a command's sweep statistics and algorithm
/// makespans come from the *same* timing model.
fn model_backend_factory(
    args: &[String],
) -> Result<Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync>> {
    let name = opt(args, "--backend").unwrap_or("sim").to_string();
    exec::parse_model_backend(&name).map_err(anyhow::Error::from)?;
    Ok(Box::new(move || {
        exec::parse_model_backend(&name).expect("spelling validated above")
    }))
}

// ---------------------------------------------------------------------------
// tracing (--trace FILE[:FMT])
// ---------------------------------------------------------------------------

/// Events a `--trace FILE:chrome` run can hold before the ring drops
/// the oldest; generous next to any CLI-sized run.
const TRACE_RING_CAP: usize = 1 << 20;

/// The recording half of `--trace FILE[:FMT]`. `FILE:chrome` records
/// into a large ring and exports Chrome trace-event JSON after the run
/// (load in chrome://tracing or Perfetto); `FILE:jsonl` — or a bare
/// FILE — streams one JSON event per line, summarized later by
/// `kreorder trace inspect FILE`.
enum TraceOut {
    Jsonl {
        path: String,
        sink: kreorder::obs::JsonlSink,
    },
    Chrome {
        path: String,
        ring: kreorder::obs::RingSink,
    },
}

impl TraceOut {
    /// Parse `--trace` from the arg list; `None` means untraced — the
    /// engines then run the strict no-op sink and stay bit-identical
    /// with the pre-tracing behavior.
    fn from_args(args: &[String]) -> Option<TraceOut> {
        let spec = opt(args, "--trace")?;
        // Only a literal `:chrome` / `:jsonl` suffix selects a format;
        // any other colon stays part of the path.
        let (path, chrome) = match spec.rsplit_once(':') {
            Some((p, "chrome")) if !p.is_empty() => (p, true),
            Some((p, "jsonl")) if !p.is_empty() => (p, false),
            _ => (spec, false),
        };
        Some(if chrome {
            TraceOut::Chrome {
                path: path.to_string(),
                ring: kreorder::obs::RingSink::new(TRACE_RING_CAP),
            }
        } else {
            TraceOut::Jsonl {
                path: path.to_string(),
                sink: kreorder::obs::JsonlSink::new(path),
            }
        })
    }

    /// The sink to hand the engine.
    fn sink(&mut self) -> &mut dyn TraceSink {
        match self {
            TraceOut::Jsonl { sink, .. } => sink,
            TraceOut::Chrome { ring, .. } => ring,
        }
    }

    /// Write the artifact after the run.
    fn finish(self) -> Result<()> {
        match self {
            TraceOut::Jsonl { path, mut sink } => {
                sink.flush().with_context(|| format!("writing trace {path}"))?;
                eprintln!(
                    "wrote trace -> {path} (inspect with `kreorder trace inspect {path}`)"
                );
            }
            TraceOut::Chrome { path, ring } => {
                let json = kreorder::obs::export::chrome_trace_json(&ring.snapshot());
                std::fs::write(&path, json)
                    .with_context(|| format!("writing trace {path}"))?;
                eprintln!(
                    "wrote Chrome trace -> {path} (load in chrome://tracing or Perfetto)"
                );
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// table3
// ---------------------------------------------------------------------------

fn cmd_table3(args: &[String]) -> Result<()> {
    let gpu = GpuSpec::gtx580();
    let make_backend = model_backend_factory(args)?;
    let experiments = match opt(args, "--exp") {
        Some(id) => vec![by_id(id).with_context(|| format!("unknown experiment `{id}`"))?],
        None => all_experiments(),
    };

    let mut table = Table3::default();
    for e in &experiments {
        eprintln!(
            "sweeping {} ({} kernels, {} permutations)…",
            e.name,
            e.kernels.len(),
            (1..=e.kernels.len()).product::<usize>()
        );
        let row = run_experiment(&gpu, e.name, &e.kernels, make_backend.as_ref())?;
        table.push(row);
    }
    println!("\n{}", table.to_markdown());
    if let Some(path) = opt(args, "--csv") {
        std::fs::write(path, table.to_csv())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn run_experiment(
    gpu: &GpuSpec,
    name: &str,
    kernels: &[kreorder::gpu::KernelProfile],
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
) -> Result<ExperimentRow> {
    sim::validate_workload(gpu, kernels).map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
    // Sweep and algorithm makespan must share one timing model, or the
    // percentile column is meaningless.
    let sw = sweep_with(gpu, kernels, make_backend);
    let sched = reorder(gpu, kernels);
    let t_alg = make_backend().execute(gpu, kernels, &sched.order).makespan_ms;
    Ok(ExperimentRow {
        name: name.to_string(),
        optimal_ms: sw.best_ms,
        worst_ms: sw.worst_ms,
        algorithm_ms: t_alg,
        percentile: sw.percentile_rank(t_alg),
        n_perms: sw.n_perms,
    })
}

// ---------------------------------------------------------------------------
// fig1
// ---------------------------------------------------------------------------

fn cmd_fig1(args: &[String]) -> Result<()> {
    let gpu = GpuSpec::gtx580();
    let e = by_id("epbsessw-8").unwrap();
    let make_backend = model_backend_factory(args)?;
    let bins: usize = opt(args, "--bins").map_or(60, |s| s.parse().unwrap_or(60));
    let out_dir = opt(args, "--out-dir").unwrap_or(".");

    eprintln!("sweeping EpBsEsSw-8 (40320 permutations)…");
    // Sweep distribution and the algorithm marker share one timing model.
    let sw = sweep_with(&gpu, &e.kernels, make_backend.as_ref());
    let sched = reorder(&gpu, &e.kernels);
    let t_alg = make_backend()
        .execute(&gpu, &e.kernels, &sched.order)
        .makespan_ms;
    let median = sw.median_ms();

    // Ranking curve: sorted times, ascending (Fig. 1 top panel).
    let sorted = sw.sorted_times();
    let mut ranking = String::from("rank,makespan_ms\n");
    for (i, t) in sorted.iter().enumerate() {
        ranking.push_str(&format!("{},{:.6}\n", i + 1, t));
    }
    let ranking_path = format!("{out_dir}/fig1_ranking.csv");
    std::fs::write(&ranking_path, ranking)?;

    // Distribution histogram (Fig. 1 bottom panel).
    let hist = Histogram::build(&sw.times, bins);
    let dist_path = format!("{out_dir}/fig1_distribution.csv");
    std::fs::write(&dist_path, hist.to_csv())?;

    println!("EpBsEsSw-8 permutation space (n = {}):", sw.n_perms);
    println!("  optimal   : {:>10.2} ms  (order {:?})", sw.best_ms, sw.best_order);
    println!("  worst     : {:>10.2} ms  (order {:?})", sw.worst_ms, sw.worst_order);
    println!("  median    : {:>10.2} ms", median);
    println!(
        "  algorithm : {:>10.2} ms  (order {:?}, rounds {:?})",
        t_alg, sched.order, sched.rounds
    );
    println!("  percentile rank     : {:.1}%", sw.percentile_rank(t_alg));
    println!("  speedup over worst  : {:.3}x", sw.worst_ms / t_alg);
    println!(
        "  deviation from opt  : {:.2}%",
        (t_alg - sw.best_ms) / sw.best_ms * 100.0
    );
    println!(
        "  gain over median (50% of random choices): {:.1}%",
        (median - t_alg) / median * 100.0
    );
    println!("wrote {ranking_path}, {dist_path}");
    Ok(())
}

// ---------------------------------------------------------------------------
// sweep
// ---------------------------------------------------------------------------

fn cmd_sweep(args: &[String]) -> Result<()> {
    let gpu = GpuSpec::gtx580();
    let id = opt(args, "--exp").context("--exp required")?;
    let e = by_id(id).with_context(|| format!("unknown experiment `{id}`"))?;
    let make_backend = model_backend_factory(args)?;
    let backend_name = opt(args, "--backend").unwrap_or("sim");
    let sw = sweep_with(&gpu, &e.kernels, make_backend.as_ref());
    let sorted = sw.sorted_times();
    println!(
        "{}: {} permutations ({backend_name} backend)",
        e.name, sw.n_perms
    );
    println!("  best   {:.2} ms  {:?}", sw.best_ms, sw.best_order);
    println!("  p25    {:.2} ms", kreorder::metrics::percentile(sorted, 25.0));
    println!("  median {:.2} ms", sw.median_ms());
    println!("  p75    {:.2} ms", kreorder::metrics::percentile(sorted, 75.0));
    println!("  worst  {:.2} ms  {:?}", sw.worst_ms, sw.worst_order);
    Ok(())
}

// ---------------------------------------------------------------------------
// search
// ---------------------------------------------------------------------------

fn cmd_search(args: &[String]) -> Result<()> {
    use kreorder::search::{
        parse_strategy, parse_strategy_reference, strategy_help_table, SearchBudget,
    };
    use kreorder::workloads::{
        all_dag_scenarios, all_scenarios, dag_scenario_by_id, parse_deps, scenario_by_id,
        Workload,
    };

    if flag(args, "--list") {
        println!("search strategies:");
        print!("{}", strategy_help_table());
        println!("\nscenario families (--scenario FAMILY:N):");
        for sc in all_scenarios() {
            println!("  {:<14} {}", sc.id, sc.description);
        }
        println!("\ndependency (DAG) scenario families (--scenario FAMILY:N):");
        for sc in all_dag_scenarios() {
            println!("  {:<14} {}", sc.id, sc.description);
        }
        return Ok(());
    }

    let gpu = GpuSpec::gtx580();
    let seed: u64 = opt(args, "--seed").map_or(0, |s| s.parse().unwrap_or(0));
    let mut workload: Workload = if let Some(id) = opt(args, "--exp") {
        Workload::independent(
            by_id(id)
                .with_context(|| format!("unknown experiment `{id}`"))?
                .kernels,
        )
    } else if let Some(n) = opt(args, "--synthetic") {
        let n: usize = n.parse().context("bad --synthetic")?;
        Workload::independent(synthetic_workload(&gpu, n, seed))
    } else if let Some(spec) = opt(args, "--scenario") {
        let (family, n) = spec
            .split_once(':')
            .context("--scenario takes FAMILY:N, e.g. skewed:16 or chain:16")?;
        let n: usize = n.parse().context("bad scenario size")?;
        if let Some(sc) = scenario_by_id(family) {
            Workload::independent(sc.workload(&gpu, n, seed))
        } else if let Some(sc) = dag_scenario_by_id(family) {
            sc.workload(&gpu, n, seed)
        } else {
            bail!("unknown scenario family `{family}` (see `kreorder search --list`)");
        }
    } else {
        bail!("need --exp ID, --synthetic N or --scenario FAMILY:N (or --list)");
    };
    if let Some(spec) = opt(args, "--deps") {
        // `--deps` takes an inline spec (`0->2;1->2`) or a kreorder-deps
        // CSV file; edges add to whatever the scenario already carries.
        let text = if std::path::Path::new(spec).is_file() {
            std::fs::read_to_string(spec).with_context(|| format!("reading deps {spec}"))?
        } else {
            spec.to_string()
        };
        workload
            .deps
            .extend(parse_deps(&text).map_err(anyhow::Error::from)?);
    }
    if workload.kernels.is_empty() {
        bail!("empty workload: need at least one kernel to search");
    }
    sim::validate_workload(&gpu, &workload.kernels).map_err(|e| anyhow::anyhow!("{e}"))?;
    let graph = workload.dep_graph().map_err(anyhow::Error::from)?;

    let strategy_name = opt(args, "--strategy").unwrap_or("bnb");
    let strategy = parse_strategy(strategy_name).map_err(anyhow::Error::from)?;
    // Default budget: unlimited for the exact solver (prove optimality),
    // the 10k-eval CI-gate budget for anytime strategies.
    let budget = match opt(args, "--budget") {
        Some(b) => SearchBudget::evals(b.parse().context("bad --budget")?),
        None if strategy.name() == "bnb" => SearchBudget::unlimited(),
        None => SearchBudget::default(),
    };
    let make_backend = model_backend_factory(args)?;

    let n = workload.n();
    let order_count = if graph.has_deps() {
        match graph.linear_extension_count() {
            Some(ext) => format!(
                "{ext} topological orders of {} total",
                if n <= 20 {
                    format!("{:.3e}", (1..=n).map(|i| i as f64).product::<f64>())
                } else {
                    "≫ 10^18".into()
                }
            ),
            None => "topological orders only".into(),
        }
    } else if n <= 20 {
        format!("{:.3e} orders", (1..=n).map(|i| i as f64).product::<f64>())
    } else {
        "≫ 10^18 orders".into()
    };
    eprintln!("searching {n} kernels ({order_count}) with {}…", strategy.name());
    let out = if graph.has_deps() {
        strategy.search_dag(&gpu, &workload, make_backend.as_ref(), &budget)
    } else {
        strategy.search(&gpu, &workload.kernels, make_backend.as_ref(), &budget)
    };

    println!("strategy   : {}", out.strategy);
    println!("best       : {:.4} ms", out.best_ms);
    println!("order      : {:?}", out.best_order);
    println!(
        "evals      : {} ({} subtrees pruned)",
        out.evals, out.pruned_subtrees
    );
    println!("wall       : {:.1} ms", out.wall_ms);
    println!(
        "optimal    : {}",
        if out.complete {
            "proven (branch-and-bound ran to completion)"
        } else {
            "not proven (anytime result / budget exhausted)"
        }
    );
    if flag(args, "--trajectory") {
        println!("incumbent trajectory (eval -> best ms):");
        for s in &out.trajectory {
            println!("  {:>10} {:.4}", s.eval, s.best_ms);
        }
    }
    if let Some(mut t) = TraceOut::from_args(args) {
        // Decision-level search introspection: the incumbent trajectory
        // as typed events, down-sampled by --trace-sample (every k-th
        // improvement plus always the final incumbent).
        let sample: u64 = opt(args, "--trace-sample").map_or(1, |s| s.parse().unwrap_or(1));
        for ev in kreorder::obs::trajectory_events(&out, sample) {
            t.sink().record(ev);
        }
        t.finish()?;
    }

    if flag(args, "--compare-eval") && graph.has_deps() {
        eprintln!(
            "note: --compare-eval skipped (the reference configurations exercise the \
             unconstrained evaluation paths; use --compare-sweep to cross-check a DAG run)"
        );
    } else if flag(args, "--compare-eval") {
        // Field-debugging aid for the fast evaluation paths: re-run the
        // same strategy in its reference configuration (anytime: full
        // per-candidate evaluation instead of the prefix-reuse cursor;
        // bnb: identical-kernel symmetry collapse disabled), print both
        // throughputs, and verify the incumbents are bit-identical.
        let reference = parse_strategy_reference(strategy_name).map_err(anyhow::Error::from)?;
        let is_bnb = strategy.name() == "bnb";
        if is_bnb && budget.max_evals.is_some() {
            bail!(
                "--compare-eval with bnb needs an unbudgeted run (omit --budget): a \
                 budget-capped parallel solve is not run-to-run deterministic, so the \
                 comparison would be meaningless"
            );
        }
        let what = if is_bnb {
            "symmetry collapse disabled"
        } else {
            "full (non-incremental) evaluation"
        };
        eprintln!("re-running with {what}…");
        let full = reference.search(&gpu, &workload.kernels, make_backend.as_ref(), &budget);
        let rate = |evals: u64, wall_ms: f64| evals as f64 / (wall_ms / 1e3).max(1e-9);
        println!(
            "eval rate  : {:.0} evals/s fast vs {:.0} evals/s reference ({:.2}x, {} vs {} evals)",
            rate(out.evals, out.wall_ms),
            rate(full.evals, full.wall_ms),
            (rate(out.evals, out.wall_ms) / rate(full.evals, full.wall_ms)).max(0.0),
            out.evals,
            full.evals
        );
        let identical = out.best_ms.to_bits() == full.best_ms.to_bits()
            && out.best_order == full.best_order
            && (is_bnb || out.trajectory.len() == full.trajectory.len());
        if identical {
            println!("incumbents : identical (bit-exact) — the fast path is a pure speedup");
        } else {
            bail!(
                "incumbent drift between fast and reference paths: ({}, {:?}) vs ({}, {:?})",
                out.best_ms,
                out.best_order,
                full.best_ms,
                full.best_order
            );
        }
    }

    if flag(args, "--compare-sweep") {
        if graph.has_deps() {
            // The DAG sweep wall is the linear-extension count, not n!:
            // a 20-kernel chain has exactly one order, a wide antichain
            // explodes. Guard on the actual count.
            const DAG_SWEEP_WALL: u128 = 5_000_000;
            match graph.linear_extension_count() {
                Some(ext) if ext <= DAG_SWEEP_WALL => {
                    eprintln!("sweeping all {ext} topological orders for comparison…");
                    let sw = kreorder::perm::sweep_dag_with(
                        &gpu,
                        &workload.kernels,
                        &graph,
                        make_backend.as_ref(),
                    );
                    println!(
                        "sweep      : best {:.4} ms over {} topological orders",
                        sw.best_ms, sw.n_perms
                    );
                    println!(
                        "gap        : {:+.4}% vs constrained-sweep optimum",
                        (out.best_ms - sw.best_ms) / sw.best_ms * 100.0
                    );
                    if out.complete
                        && (out.best_ms.to_bits() != sw.best_ms.to_bits()
                            || out.best_order != sw.best_order)
                    {
                        bail!(
                            "complete DAG search drifted from the constrained sweep: \
                             ({}, {:?}) vs ({}, {:?})",
                            out.best_ms,
                            out.best_order,
                            sw.best_ms,
                            sw.best_order
                        );
                    }
                }
                _ => eprintln!(
                    "note: --compare-sweep skipped (too many topological orders to enumerate)"
                ),
            }
        } else if n > 11 {
            eprintln!("note: --compare-sweep skipped (n = {n} > 11 is past the sweep wall)");
        } else {
            eprintln!("sweeping all orders for comparison…");
            let stats = kreorder::perm::sweep_stats_with(
                &gpu,
                &workload.kernels,
                make_backend.as_ref(),
                4096,
            );
            println!("sweep      : best {:.4} ms, worst {:.4} ms", stats.best_ms, stats.worst_ms);
            println!(
                "percentile : {:.2}% of all {} orders (histogram resolution)",
                stats.percentile_rank(out.best_ms),
                stats.n_perms
            );
            println!(
                "gap        : {:+.4}% vs sweep optimum",
                (out.best_ms - stats.best_ms) / stats.best_ms * 100.0
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// sched
// ---------------------------------------------------------------------------

fn cmd_sched(args: &[String]) -> Result<()> {
    let gpu = GpuSpec::gtx580();
    let mut backend = model_backend(args)?;
    let kernels = if let Some(id) = opt(args, "--exp") {
        by_id(id)
            .with_context(|| format!("unknown experiment `{id}`"))?
            .kernels
    } else if let Some(n) = opt(args, "--synthetic") {
        let n: usize = n.parse().context("bad --synthetic")?;
        let seed: u64 = opt(args, "--seed").map_or(0, |s| s.parse().unwrap_or(0));
        synthetic_workload(&gpu, n, seed)
    } else {
        bail!("need --exp ID or --synthetic N");
    };
    sim::validate_workload(&gpu, &kernels).map_err(|e| anyhow::anyhow!("{e}"))?;

    println!("kernels:");
    for (i, k) in kernels.iter().enumerate() {
        let f = k.per_sm_footprint(&gpu);
        println!(
            "  [{i}] {:<18} grid {:>3}  warps/SM {:>4}  shm/SM {:>6}  regs/SM {:>6}  R {:>6.2}",
            k.name, k.n_blocks, f.warps, f.shmem, f.regs, k.ratio
        );
    }

    let sched = reorder(&gpu, &kernels);
    println!("\nAlgorithm 1 order: {:?}", sched.order);
    for (r, round) in sched.rounds.iter().enumerate() {
        let names: Vec<&str> = round.iter().map(|&i| kernels[i].name.as_str()).collect();
        let ratio = sim::rounds::combined_ratio(&kernels, round);
        println!("  round {r}: {names:?}  R_comb {ratio:.2}");
    }

    println!("\n{} makespan per registered policy:", backend.name());
    for policy in registry::all_policies() {
        let order = policy.order(&gpu, &kernels);
        let r = backend.execute(&gpu, &kernels, &order);
        println!(
            "  {:<18} {:>10.2} ms",
            policy.name(),
            r.makespan_ms
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

fn cmd_serve(args: &[String]) -> Result<()> {
    // Online mode: a deterministic virtual-clock run of the streaming
    // scheduler (no threads, no wall clock) — selected by --arrivals.
    if flag(args, "--list-online") {
        println!("arrival processes (--arrivals):");
        print!("{}", kreorder::online::arrival_help_table());
        println!("\nwindow policies (--window):");
        print!("{}", kreorder::online::window_policy_help_table());
        println!("\nscenario families (--scenario): see `kreorder search --list`");
        return Ok(());
    }
    if let Some(spec) = opt(args, "--arrivals") {
        let spec = spec.to_string();
        return cmd_serve_online(args, &spec);
    }
    let batches: usize = opt(args, "--batches").map_or(8, |s| s.parse().unwrap_or(8));
    let window: usize = opt(args, "--window").map_or(8, |s| s.parse().unwrap_or(8));
    let devices: usize = opt(args, "--devices").map_or(1, |s| s.parse().unwrap_or(1));
    let seed: u64 = opt(args, "--seed").map_or(0, |s| s.parse().unwrap_or(0));
    let policy_name = opt(args, "--policy").unwrap_or("algorithm1");
    let sim_only = flag(args, "--sim-only");
    let backend_name = opt(args, "--backend");

    let gpu = GpuSpec::gtx580();
    let mut builder = CoordinatorBuilder::new()
        .gpu(gpu.clone())
        .policy_named(policy_name)
        .map_err(anyhow::Error::from)?
        .devices(devices)
        .window(window)
        .linger(Duration::from_millis(5));

    // Backend selection: explicit --backend wins; otherwise PJRT payloads
    // when compiled in and not --sim-only; otherwise the simulator.
    if backend_name == Some("pjrt") && sim_only {
        bail!("--backend pjrt and --sim-only are contradictory; pick one");
    }
    match backend_name {
        Some("pjrt") | None if !sim_only => {
            let artifacts = opt(args, "--artifacts")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(ArtifactStore::default_dir);
            #[cfg(feature = "pjrt")]
            {
                builder = builder.pjrt_backend(artifacts);
            }
            #[cfg(not(feature = "pjrt"))]
            {
                if backend_name == Some("pjrt") {
                    bail!(
                        "--backend pjrt needs a build with --features pjrt \
                         (artifacts at {})",
                        artifacts.display()
                    );
                }
                eprintln!(
                    "note: built without the `pjrt` feature — serving simulation-only"
                );
            }
        }
        Some(name) => {
            // Validate the spelling, then install a fresh instance per
            // device worker.
            let _ = exec::parse_model_backend(name).map_err(anyhow::Error::from)?;
            let name = name.to_string();
            builder = builder.backend(move || {
                exec::parse_model_backend(&name).map_err(anyhow::Error::from)
            });
        }
        None => {} // --sim-only with no --backend: simulator default
    }

    println!(
        "coordinator: policy={policy_name} window={window} devices={devices} sim_only={sim_only}"
    );
    let coord = builder.start();

    let mut rng = SplitMix64::new(seed);
    let mut handles = Vec::new();
    let mut next_id = 0u64;
    for b in 0..batches {
        let kernels = synthetic_workload(&gpu, window, seed.wrapping_add(b as u64));
        for k in kernels {
            handles.push(coord.submit(LaunchRequest {
                id: next_id,
                profile: k,
                seed: rng.next_u64(),
            }));
            next_id += 1;
        }
        coord.flush();
    }

    for h in handles {
        let r = h.wait()?;
        if r.checksum == f64::NEG_INFINITY {
            eprintln!("request {} FAILED", r.id);
        }
    }
    let (reports, stats) = coord.shutdown();

    println!("\nper-batch (simulated GTX580 makespan):");
    println!("  batch  dev   n   fifo(ms)   policy(ms)  speedup   exec-wall(ms)");
    for r in &reports {
        println!(
            "  {:>5} {:>4} {:>3} {:>10.2} {:>11.2} {:>8.3}x {:>12.2}",
            r.batch_id,
            r.device,
            r.n,
            r.sim_fifo_ms,
            r.sim_policy_ms,
            r.sim_fifo_ms / r.sim_policy_ms,
            r.exec_wall_ms
        );
    }
    println!("\n{}", stats.summary());
    println!("throughput: {:.1} kernels/s", stats.throughput_per_s());
    Ok(())
}

/// `serve --arrivals …`: the online streaming scheduler on the virtual
/// clock. Fully deterministic per (arrival seed, strategy seed, window
/// policy): two runs print bit-identical latency numbers.
fn cmd_serve_online(args: &[String], arrivals: &str) -> Result<()> {
    use kreorder::online::{
        offline_oracle, parse_window_policy, shed_csv, simulate_online_traced, ArrivalSource,
        ArrivalSpec, ClosedLoopSource, OnlineOpts, OnlineReorderer, ReplaySource, Trace,
    };
    use kreorder::workloads::scenario_by_id;

    let gpu = GpuSpec::gtx580();
    let count: usize = opt(args, "--count").map_or(64, |s| s.parse().unwrap_or(64));
    let family_name = opt(args, "--scenario").unwrap_or("mixed");
    let window_spec = opt(args, "--window").unwrap_or("linger:8:50");
    let strategy = opt(args, "--strategy").unwrap_or("local:0");
    let budget: u64 = opt(args, "--budget").map_or(256, |s| s.parse().unwrap_or(256));
    let decision_cost: f64 =
        opt(args, "--decision-cost").map_or(0.0, |s| s.parse().unwrap_or(0.0));
    let slo_ms: Option<f64> = opt(args, "--slo").and_then(|s| s.parse().ok());
    // Overload protection at the door. `none` (the default) is a strict
    // no-op: the run bit-matches the ungated engine.
    let mut admission = kreorder::registry::parse_admission(
        opt(args, "--admission").unwrap_or("none"),
    )
    .map_err(anyhow::Error::from)?;

    let spec = ArrivalSpec::parse(arrivals).map_err(anyhow::Error::from)?;
    let family = scenario_by_id(family_name)
        .with_context(|| format!("unknown scenario family `{family_name}`"))?;

    // Materialize the source. Open-loop processes go through a Trace so
    // the realized schedule can be recorded; replay reads one back.
    let (source, trace): (Box<dyn ArrivalSource>, Option<Trace>) = match &spec {
        ArrivalSpec::Replay { path } => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading trace {path}"))?;
            let trace = Trace::parse(&text).map_err(anyhow::Error::from)?;
            eprintln!(
                "replaying {}: family={} n={} seed={}",
                path, trace.family, trace.n, trace.seed
            );
            let src = ReplaySource::from_trace(&trace, &gpu).map_err(anyhow::Error::from)?;
            (Box::new(src), Some(trace))
        }
        ArrivalSpec::Closed {
            clients,
            think_ms,
            seed,
        } => {
            let src = ClosedLoopSource::new(family, &gpu, count, *clients, *think_ms, *seed);
            (Box::new(src), None)
        }
        _ => {
            let trace = spec.trace(family.id, count).expect("open-loop spec");
            let src = ReplaySource::from_trace(&trace, &gpu)
                .map_err(anyhow::Error::from)?
                .named(spec.name());
            (Box::new(src), Some(trace))
        }
    };

    let window = parse_window_policy(window_spec).map_err(anyhow::Error::from)?;
    let mut reorderer = if strategy.eq_ignore_ascii_case("fifo") {
        OnlineReorderer::fifo()
    } else {
        OnlineReorderer::search(strategy, budget).map_err(anyhow::Error::from)?
    };
    if let Some(spec) = opt(args, "--deps") {
        // A within-window dependency template: inline (`0->2;1->2`) or a
        // kreorder-deps CSV file. Positions index arrival order inside
        // each window; edges must point forward so FIFO stays feasible.
        let text = if std::path::Path::new(spec).is_file() {
            std::fs::read_to_string(spec).with_context(|| format!("reading deps {spec}"))?
        } else {
            spec.to_string()
        };
        let edges = kreorder::workloads::parse_deps(&text).map_err(anyhow::Error::from)?;
        reorderer = reorderer.with_deps(&edges).map_err(anyhow::Error::from)?;
    }
    let make_backend = model_backend_factory(args)?;
    let opts = OnlineOpts {
        decision_ms_per_eval: decision_cost,
    };

    println!(
        "online: arrivals={} scenario={} window={} reorderer={} backend={} decision-cost={} \
         admission={}",
        spec.name(),
        family.id,
        window.name(),
        reorderer.name(),
        opt(args, "--backend").unwrap_or("sim"),
        decision_cost,
        admission.name(),
    );
    let mut tracer = TraceOut::from_args(args);
    let mut untraced = kreorder::obs::NoTrace;
    let report = simulate_online_traced(
        &gpu,
        source,
        window,
        &reorderer,
        make_backend.as_ref(),
        &opts,
        admission.as_mut(),
        match tracer.as_mut() {
            Some(t) => t.sink(),
            None => &mut untraced,
        },
    );
    if let Some(t) = tracer {
        t.finish()?;
    }
    println!("{}", report.summary());
    for s in &report.shed {
        println!("  shed kernel {} (arrived {:.2} ms): {}", s.id, s.arrival_ms, s.cause);
    }

    // Distribution panel at histogram resolution.
    let hist = report.sojourn_histogram(64);
    println!(
        "  sojourn histogram (64 bins): p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
        hist.percentile(50.0),
        hist.percentile(90.0),
        hist.percentile(99.0)
    );
    if let Some(slo) = slo_ms {
        println!(
            "  SLO {slo} ms: {:.2}% attained",
            report.slo_attainment(slo) * 100.0
        );
    }

    // Pool seed: open-loop traces carry it; the closed loop uses its own.
    let pool_seed = match &spec {
        ArrivalSpec::Closed { seed, .. } => *seed,
        _ => 0,
    };

    if flag(args, "--oracle") {
        // The clairvoyant full-trace baseline: all kernels at t=0, one
        // optimally ordered batch.
        let pool = match &trace {
            Some(t) => t.pool(&gpu).expect("family validated above"),
            None => family.workload(&gpu, count, pool_seed),
        };
        let oracle = offline_oracle(&gpu, &pool, make_backend.as_ref(), 20_000);
        println!(
            "  offline oracle ({}): makespan {:.2} ms | online span {:.2} ms | \
             price of onlineness {:.3}x",
            oracle.method,
            oracle.makespan_ms,
            report.span_ms,
            report.span_ms / oracle.makespan_ms
        );
    }

    if let Some(path) = opt(args, "--record") {
        // Record the realized arrival schedule (for closed loop: the
        // schedule its completions produced) for bit-exact replay.
        let recorded = match trace {
            Some(t) => t,
            None => {
                // Shed arrivals are arrivals too: the replayed schedule
                // must offer the same load the closed loop realized.
                let mut times: Vec<f64> = report
                    .kernels
                    .iter()
                    .map(|k| k.arrival_ms)
                    .chain(report.shed.iter().map(|s| s.arrival_ms))
                    .collect();
                times.sort_by(|a, b| a.total_cmp(b));
                Trace {
                    family: family.id.to_string(),
                    n: times.len(),
                    seed: pool_seed,
                    devices: 1,
                    times_ms: times,
                }
            }
        };
        // The shed ledger rides along as `#` comment rows (ignored by
        // `Trace::parse`), so a recorded overload run keeps its full
        // conservation story on disk.
        let mut csv = recorded.to_csv();
        csv.push_str(&shed_csv(&report.shed));
        std::fs::write(path, csv)?;
        eprintln!("recorded trace -> {path} (replay with --arrivals replay:{path})");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// fleet
// ---------------------------------------------------------------------------

/// Read a recorded trace and check it fits this fleet (a trace recorded
/// on D devices must replay on at least D).
fn load_fleet_trace(
    path: &str,
    fleet: &kreorder::fleet::FleetSpec,
) -> Result<kreorder::online::Trace> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let trace = kreorder::online::Trace::parse(&text).map_err(anyhow::Error::from)?;
    fleet.validate_trace(&trace).map_err(anyhow::Error::from)?;
    eprintln!(
        "replaying {}: family={} n={} seed={} devices={}",
        path, trace.family, trace.n, trace.seed, trace.devices
    );
    Ok(trace)
}

/// `fleet`: multi-device online scheduling on the virtual clock — a
/// routing policy fans arrivals out over a (possibly heterogeneous)
/// fleet, each device running its own reorder window. Deterministic per
/// (arrival seed, route policy, window policy, strategy seed): two runs
/// print bit-identical numbers.
fn cmd_fleet(args: &[String]) -> Result<()> {
    use kreorder::fault::FaultConfig;
    use kreorder::fleet::{
        fleet_lower_bound, p99_speedup, parse_route_policy, route_policy_help_table,
        simulate_fleet_traced, simulate_fleet_with_admission, FleetSpec,
    };
    use kreorder::online::{
        parse_window_policy, shed_csv, ArrivalSource, ArrivalSpec, ClosedLoopSource, OnlineOpts,
        OnlineReorderer, ReplaySource, Trace,
    };
    use kreorder::workloads::scenario_by_id;

    if flag(args, "--list-routes") {
        println!("route policies (--route):");
        print!("{}", route_policy_help_table());
        println!("\ndevice specs (--devices):");
        println!("  a device count (`4`), or a comma list of speed factors");
        println!("  `<speed>` / `<count>x<speed>` (e.g. `1,1,0.5`, `2x1,2x0.25`)");
        println!("\nwindow policies (--window): see `kreorder serve --list-online`");
        return Ok(());
    }

    let gpu = GpuSpec::gtx580();
    let fleet =
        FleetSpec::parse(opt(args, "--devices").unwrap_or("2")).map_err(anyhow::Error::from)?;
    let route_spec = opt(args, "--route").unwrap_or("jsq");
    let count: usize = opt(args, "--count").map_or(64, |s| s.parse().unwrap_or(64));
    let family_name = opt(args, "--scenario").unwrap_or("mixed");
    let window_spec = opt(args, "--window").unwrap_or("linger:8:50");
    let strategy = opt(args, "--strategy").unwrap_or("local:0");
    let budget: u64 = opt(args, "--budget").map_or(256, |s| s.parse().unwrap_or(256));
    let decision_cost: f64 =
        opt(args, "--decision-cost").map_or(0.0, |s| s.parse().unwrap_or(0.0));
    // Overload protection at the door; re-parsed per run because the
    // policy is stateful (CoDel) and the baseline must start fresh.
    let admission_spec = opt(args, "--admission").unwrap_or("none");
    let make_admission = || kreorder::registry::parse_admission(admission_spec);
    make_admission().map_err(anyhow::Error::from)?;

    let family = scenario_by_id(family_name)
        .with_context(|| format!("unknown scenario family `{family_name}`"))?;

    // Materialize the arrival schedule. `--replay FILE` (or `--arrivals
    // replay:FILE`) reads a recorded trace back and checks it fits this
    // fleet; open-loop specs go through a Trace so the realized
    // schedule can be recorded; the closed loop reacts to completions.
    let mut closed: Option<(usize, f64, u64)> = None;
    let trace: Option<Trace> = if let Some(path) = opt(args, "--replay") {
        Some(load_fleet_trace(path, &fleet)?)
    } else {
        let arrivals = opt(args, "--arrivals").unwrap_or("poisson:400:1");
        let spec = ArrivalSpec::parse(arrivals).map_err(anyhow::Error::from)?;
        match &spec {
            ArrivalSpec::Replay { path } => Some(load_fleet_trace(path, &fleet)?),
            ArrivalSpec::Closed {
                clients,
                think_ms,
                seed,
            } => {
                closed = Some((*clients, *think_ms, *seed));
                None
            }
            _ => Some(spec.trace(family.id, count).expect("open-loop spec")),
        }
    };

    // Source factory: `--compare-roundrobin` replays the identical
    // schedule through the baseline router.
    let make_source = || -> Result<Box<dyn ArrivalSource>> {
        Ok(match (&trace, closed) {
            (Some(t), _) => {
                Box::new(ReplaySource::from_trace(t, &gpu).map_err(anyhow::Error::from)?)
            }
            (None, Some((clients, think_ms, seed))) => {
                Box::new(ClosedLoopSource::new(family, &gpu, count, clients, think_ms, seed))
            }
            (None, None) => unreachable!("either a trace or closed-loop params exist"),
        })
    };

    // Validate the window spelling once; each device then builds its own
    // policy instance from it.
    parse_window_policy(window_spec).map_err(anyhow::Error::from)?;
    let make_window = || parse_window_policy(window_spec).expect("validated above");
    let reorderer = if strategy.eq_ignore_ascii_case("fifo") {
        OnlineReorderer::fifo()
    } else {
        OnlineReorderer::search(strategy, budget).map_err(anyhow::Error::from)?
    };
    let make_backend = model_backend_factory(args)?;
    let opts = OnlineOpts {
        decision_ms_per_eval: decision_cost,
    };

    println!(
        "fleet: devices={} route={} window={} reorderer={} backend={} decision-cost={} \
         admission={}",
        fleet.name(),
        route_spec,
        window_spec,
        reorderer.name(),
        opt(args, "--backend").unwrap_or("sim"),
        decision_cost,
        admission_spec,
    );
    let mut tracer = TraceOut::from_args(args);
    let mut untraced = kreorder::obs::NoTrace;
    let report = simulate_fleet_traced(
        &fleet,
        make_source()?,
        parse_route_policy(route_spec).map_err(anyhow::Error::from)?,
        &make_window,
        &reorderer,
        make_backend.as_ref(),
        &opts,
        &FaultConfig::default(),
        make_admission().expect("validated above").as_mut(),
        match tracer.as_mut() {
            Some(t) => t.sink(),
            None => &mut untraced,
        },
    );
    if let Some(t) = tracer {
        t.finish()?;
    }
    println!("{}", report.summary());
    for s in &report.shed {
        println!("  shed kernel {} (arrived {:.2} ms): {}", s.id, s.arrival_ms, s.cause);
    }

    if flag(args, "--oracle") {
        // The clairvoyant fleet baseline: every kernel at t=0, perfectly
        // routed and ordered (fluid bound — see fleet::fleet_lower_bound
        // for the jitter caveat).
        let pool = match &trace {
            Some(t) => t.pool(&gpu).context("trace family missing from the registry")?,
            None => family.workload(&gpu, count, closed.map(|(_, _, s)| s).unwrap_or(0)),
        };
        let lb = fleet_lower_bound(&fleet, &pool);
        println!(
            "  fleet oracle: lower bound {:.2} ms | span {:.2} ms | ratio {:.3}x",
            lb,
            report.span_ms,
            report.span_ms / lb.max(f64::MIN_POSITIVE)
        );
    }

    if flag(args, "--compare-roundrobin") {
        let rr = simulate_fleet_with_admission(
            &fleet,
            make_source()?,
            parse_route_policy("roundrobin").map_err(anyhow::Error::from)?,
            &make_window,
            &reorderer,
            make_backend.as_ref(),
            &opts,
            &FaultConfig::default(),
            make_admission().expect("validated above").as_mut(),
        );
        println!(
            "  roundrobin baseline: p99 {:.2} ms vs routed p99 {:.2} ms | speedup {:.3}x",
            rr.sojourn_stats().p99_ms,
            report.sojourn_stats().p99_ms,
            p99_speedup(&rr, &report)
        );
    }

    if let Some(path) = opt(args, "--record") {
        // Record the realized arrival schedule, stamped with the fleet
        // size so replay onto a smaller fleet is rejected.
        let recorded = match &trace {
            Some(t) => t.clone(),
            None => {
                // Shed arrivals are arrivals too: replay must offer the
                // same load the closed loop realized.
                let mut times: Vec<f64> = report
                    .kernels
                    .iter()
                    .map(|k| k.arrival_ms)
                    .chain(report.shed.iter().map(|s| s.arrival_ms))
                    .collect();
                times.sort_by(|a, b| a.total_cmp(b));
                Trace {
                    family: family.id.to_string(),
                    n: times.len(),
                    seed: closed.map(|(_, _, s)| s).unwrap_or(0),
                    devices: 1,
                    times_ms: times,
                }
            }
        }
        .with_devices(fleet.len());
        // Keep the shed ledger with the schedule (comment rows are
        // ignored on replay).
        let mut csv = recorded.to_csv();
        csv.push_str(&shed_csv(&report.shed));
        std::fs::write(path, csv)?;
        eprintln!("recorded fleet trace -> {path} (replay with --replay {path})");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// fault
// ---------------------------------------------------------------------------

/// `fault`: a fleet run under a deterministic fault plan — device
/// crashes (with optional recovery), slowdowns, and seeded launch
/// failures retried with exponential backoff. Fully deterministic per
/// (fault plan, fault seed, arrival seed, route/window/strategy): two
/// runs print bit-identical numbers, including the fault ledger.
fn cmd_fault(args: &[String]) -> Result<()> {
    use kreorder::fault::{fault_plan_help_table, FaultConfig, FaultPlan, RetryPolicy};
    use kreorder::fleet::{
        parse_route_policy, simulate_fleet_traced, simulate_fleet_with_admission, FleetSpec,
    };
    use kreorder::online::{
        parse_window_policy, ArrivalSource, ArrivalSpec, ClosedLoopSource, OnlineOpts,
        OnlineReorderer, ReplaySource, Trace,
    };
    use kreorder::workloads::scenario_by_id;

    if flag(args, "--list-faults") {
        println!("fault plan clauses (--plan SPEC, clauses joined with `;`):");
        print!("{}", fault_plan_help_table());
        println!("\n--plan also accepts a file holding one clause per line");
        println!("(`#` comments allowed — the `kreorder-faults` CSV format).");
        println!("--gen-faults N draws a plan from the seeded generator instead.");
        println!("\nroute policies (--route): see `kreorder fleet --list-routes`");
        println!("window policies (--window): see `kreorder serve --list-online`");
        println!("admission policies (--admission): see `kreorder list --kind admission`");
        return Ok(());
    }

    let gpu = GpuSpec::gtx580();
    let fleet =
        FleetSpec::parse(opt(args, "--devices").unwrap_or("4")).map_err(anyhow::Error::from)?;
    let fault_seed: u64 = opt(args, "--fault-seed").map_or(0, |s| s.parse().unwrap_or(0));
    let horizon_ms: f64 = opt(args, "--horizon").map_or(500.0, |s| s.parse().unwrap_or(500.0));

    // Fault plan: `--plan` takes an inline spec or a file holding one;
    // `--gen-faults N` draws a plan from the seeded generator instead.
    let plan = if let Some(spec) = opt(args, "--plan") {
        let text = if std::path::Path::new(spec).is_file() {
            std::fs::read_to_string(spec)
                .with_context(|| format!("reading fault plan {spec}"))?
        } else {
            spec.to_string()
        };
        FaultPlan::parse(&text).map_err(anyhow::Error::from)?
    } else if let Some(n) = opt(args, "--gen-faults") {
        let n: usize = n.parse().context("bad --gen-faults")?;
        FaultPlan::generate(fault_seed, fleet.len(), horizon_ms, n)
    } else {
        bail!("need --plan SPEC-OR-FILE or --gen-faults N (or --list-faults)");
    };
    fleet.validate_fault_plan(&plan).map_err(anyhow::Error::from)?;
    let retries: u32 = opt(args, "--retries").map_or(4, |s| s.parse().unwrap_or(4));
    let faults = FaultConfig {
        plan,
        retry: RetryPolicy::new(retries, fault_seed),
    };

    let route_spec = opt(args, "--route").unwrap_or("jsq");
    let count: usize = opt(args, "--count").map_or(64, |s| s.parse().unwrap_or(64));
    let family_name = opt(args, "--scenario").unwrap_or("mixed");
    let window_spec = opt(args, "--window").unwrap_or("linger:8:50");
    let strategy = opt(args, "--strategy").unwrap_or("local:0");
    let budget: u64 = opt(args, "--budget").map_or(256, |s| s.parse().unwrap_or(256));
    let decision_cost: f64 =
        opt(args, "--decision-cost").map_or(0.0, |s| s.parse().unwrap_or(0.0));
    // Overload protection composes with faults: admission sheds at the
    // door, faults shed in flight, and every arrival still lands in
    // exactly one ledger. Re-parsed per run (CoDel is stateful) so
    // `--compare-nofault` holds admission constant and varies only the
    // fault plan.
    let admission_spec = opt(args, "--admission").unwrap_or("none");
    let make_admission = || kreorder::registry::parse_admission(admission_spec);
    make_admission().map_err(anyhow::Error::from)?;

    let family = scenario_by_id(family_name)
        .with_context(|| format!("unknown scenario family `{family_name}`"))?;

    // Materialize the arrival schedule (same shapes as `fleet`): open-loop
    // specs go through a Trace so `--compare-nofault` replays the identical
    // schedule; the closed loop reacts to completions (including sheds).
    let mut closed: Option<(usize, f64, u64)> = None;
    let arrivals = opt(args, "--arrivals").unwrap_or("poisson:400:1");
    let spec = ArrivalSpec::parse(arrivals).map_err(anyhow::Error::from)?;
    let trace: Option<Trace> = match &spec {
        ArrivalSpec::Replay { path } => Some(load_fleet_trace(path, &fleet)?),
        ArrivalSpec::Closed {
            clients,
            think_ms,
            seed,
        } => {
            closed = Some((*clients, *think_ms, *seed));
            None
        }
        _ => Some(spec.trace(family.id, count).expect("open-loop spec")),
    };
    let make_source = || -> Result<Box<dyn ArrivalSource>> {
        Ok(match (&trace, closed) {
            (Some(t), _) => {
                Box::new(ReplaySource::from_trace(t, &gpu).map_err(anyhow::Error::from)?)
            }
            (None, Some((clients, think_ms, seed))) => {
                Box::new(ClosedLoopSource::new(family, &gpu, count, clients, think_ms, seed))
            }
            (None, None) => unreachable!("either a trace or closed-loop params exist"),
        })
    };

    parse_window_policy(window_spec).map_err(anyhow::Error::from)?;
    let make_window = || parse_window_policy(window_spec).expect("validated above");
    let reorderer = if strategy.eq_ignore_ascii_case("fifo") {
        OnlineReorderer::fifo()
    } else {
        OnlineReorderer::search(strategy, budget).map_err(anyhow::Error::from)?
    };
    let make_backend = model_backend_factory(args)?;
    let opts = OnlineOpts {
        decision_ms_per_eval: decision_cost,
    };

    println!(
        "fault: devices={} route={} plan={} retries={} window={} reorderer={} backend={} \
         admission={}",
        fleet.name(),
        route_spec,
        faults.plan.name(),
        faults.retry.max_attempts,
        window_spec,
        reorderer.name(),
        opt(args, "--backend").unwrap_or("sim"),
        admission_spec,
    );
    let mut tracer = TraceOut::from_args(args);
    let mut untraced = kreorder::obs::NoTrace;
    let report = simulate_fleet_traced(
        &fleet,
        make_source()?,
        parse_route_policy(route_spec).map_err(anyhow::Error::from)?,
        &make_window,
        &reorderer,
        make_backend.as_ref(),
        &opts,
        &faults,
        make_admission().expect("validated above").as_mut(),
        match tracer.as_mut() {
            Some(t) => t.sink(),
            None => &mut untraced,
        },
    );
    if let Some(t) = tracer {
        t.finish()?;
    }
    println!("{}", report.summary());
    for s in &report.shed {
        println!(
            "  shed kernel {} (arrived {:.2} ms, {} attempts): {}",
            s.id, s.arrival_ms, s.attempts, s.cause
        );
    }

    if flag(args, "--compare-nofault") {
        // The identical arrival schedule through the identical router
        // and admission gate, with the fault plan removed: isolates
        // what the faults cost.
        let clean = simulate_fleet_with_admission(
            &fleet,
            make_source()?,
            parse_route_policy(route_spec).map_err(anyhow::Error::from)?,
            &make_window,
            &reorderer,
            make_backend.as_ref(),
            &opts,
            &FaultConfig::default(),
            make_admission().expect("validated above").as_mut(),
        );
        let faulted_p99 = report.sojourn_stats().p99_ms;
        let clean_p99 = clean.sojourn_stats().p99_ms;
        println!(
            "  no-fault baseline: p99 {:.2} ms vs faulted p99 {:.2} ms | \
             degradation {:.3}x | completion rate {:.4} vs {:.4}",
            clean_p99,
            faulted_p99,
            faulted_p99 / clean_p99.max(f64::MIN_POSITIVE),
            report.completion_rate(),
            clean.completion_rate(),
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// trace
// ---------------------------------------------------------------------------

/// `trace inspect FILE`: summarize a recorded trace artifact. JSONL
/// event streams (from `--trace FILE`) fold into the deterministic
/// counters snapshot; Chrome trace-event JSON (from `--trace
/// FILE:chrome`) runs the structural validator and prints the
/// lane/span summary.
fn cmd_trace(args: &[String]) -> Result<()> {
    use kreorder::obs::export::{events_from_jsonl, validate_chrome_trace};
    use kreorder::obs::Counters;

    match args.first().map(|s| s.as_str()) {
        Some("inspect") => {}
        Some(other) => {
            bail!("unknown trace subcommand `{other}` (try `kreorder trace inspect FILE`)")
        }
        None => bail!("usage: kreorder trace inspect FILE"),
    }
    let path = args
        .get(1)
        .map(|s| s.as_str())
        .context("usage: kreorder trace inspect FILE")?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    if text.trim_start().starts_with('{') {
        let s = validate_chrome_trace(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        println!("{path}: valid Chrome trace-event JSON");
        println!(
            "  {} events | {} batch spans | {} device lanes | last timestamp {:.3} ms",
            s.n_events,
            s.n_spans,
            s.n_lanes,
            s.max_ts_us / 1e3
        );
    } else {
        let events = events_from_jsonl(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        println!("{path}: {} events", events.len());
        print!("{}", Counters::from_events(&events).render());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// ablate
// ---------------------------------------------------------------------------

fn cmd_ablate(args: &[String]) -> Result<()> {
    let gpu = GpuSpec::gtx580();
    let mut backend = model_backend(args)?;
    let experiments = match opt(args, "--exp") {
        Some(id) => vec![by_id(id).with_context(|| format!("unknown experiment `{id}`"))?],
        None => all_experiments(),
    };

    let configs: [(&str, ScoreConfig); 5] = [
        ("full", ScoreConfig::default()),
        (
            "resources-only",
            ScoreConfig {
                ratio_balance: false,
                ..ScoreConfig::default()
            },
        ),
        (
            "ratio-only",
            ScoreConfig {
                resource_balance: false,
                ..ScoreConfig::default()
            },
        ),
        (
            "no-opposing-gate",
            ScoreConfig {
                opposing_gate: false,
                ..ScoreConfig::default()
            },
        ),
        (
            "no-shm-sort",
            ScoreConfig {
                shm_sort: false,
                ..ScoreConfig::default()
            },
        ),
    ];

    println!(
        "| Experiment | {} |",
        configs
            .iter()
            .map(|(n, _)| format!("{n} (ms)"))
            .collect::<Vec<_>>()
            .join(" | ")
    );
    println!("|---|{}|", "---|".repeat(configs.len()));
    for e in &experiments {
        let mut cells = Vec::new();
        for (_, cfg) in &configs {
            let sched = reorder_with(&gpu, &e.kernels, cfg);
            let t = backend.execute(&gpu, &e.kernels, &sched.order).makespan_ms;
            cells.push(format!("{t:.2}"));
        }
        println!("| {} | {} |", e.name, cells.join(" | "));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// list
// ---------------------------------------------------------------------------

/// `list [--kind K]`: the unified registry listing — every string
/// registry's cheat sheet from one place (`kreorder::registry`). The
/// older scattered flags (`search --list`, `serve --list-online`,
/// `fleet --list-routes`, `fault --list-faults`) stay as aliases.
fn cmd_list(args: &[String]) -> Result<()> {
    use kreorder::registry::{kinds, list};
    if let Some(kind) = opt(args, "--kind") {
        let table = list(kind).with_context(|| {
            format!(
                "unknown registry kind `{kind}` — valid kinds: {}",
                kinds().join(", ")
            )
        })?;
        println!("{kind}:");
        print!("{table}");
        return Ok(());
    }
    for &kind in kinds() {
        println!("{kind}:");
        print!("{}", list(kind).expect("every registered kind lists"));
        println!();
    }
    println!("scenario families (--scenario FAMILY:N):");
    for sc in kreorder::workloads::all_scenarios() {
        println!("  {:<14} {}", sc.id, sc.description);
    }
    println!("\ndependency (DAG) scenario families (--scenario FAMILY:N):");
    for sc in kreorder::workloads::all_dag_scenarios() {
        println!("  {:<14} {}", sc.id, sc.description);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// policies
// ---------------------------------------------------------------------------

fn cmd_policies(_args: &[String]) -> Result<()> {
    println!("registered launch policies:");
    print!("{}", registry::help_table());
    println!(
        "\nAny spelling above is accepted by `serve --policy`, \
         `CoordinatorBuilder::policy_named`, and `sched::registry::parse`."
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// artifacts
// ---------------------------------------------------------------------------

fn cmd_artifacts(args: &[String]) -> Result<()> {
    let dir = opt(args, "--dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(ArtifactStore::default_dir);
    let store = ArtifactStore::load(&dir)?;
    println!("artifacts in {}:", store.dir.display());
    for name in store.variant_names() {
        let v = store.variant(&name)?;
        println!(
            "  {:<24} app={:<15} inst={:>10.3e} bytes={:>10.3e} R={:>7.3}  {}",
            name,
            v.app,
            v.profile.instructions,
            v.profile.bytes_accessed,
            v.profile.ratio,
            v.description
        );
    }
    Ok(())
}
