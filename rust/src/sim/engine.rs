//! The event-driven fluid simulation engine.
//!
//! Model summary (DESIGN.md §5):
//!
//! * **Dispatch** — the block queue is the concatenation of each kernel's
//!   blocks in launch order. Dispatch is strictly in order: the head block
//!   is placed on the least-loaded SM on which its resource vector fits;
//!   if it fits nowhere, dispatch stalls until a completion frees space
//!   (head-of-line blocking — the mechanism that makes launch order
//!   matter on Fermi-class hardware).
//! * **Compute** — each SM is a processor-sharing server: its issue
//!   throughput (`compute_rate_per_sm`, reached at `warps_to_saturate`
//!   resident warps) is divided among resident blocks in proportion to
//!   their warp counts. Below saturation, throughput scales with resident
//!   warps — this is what rewards co-residency (higher occupancy = more
//!   latency hiding).
//! * **Memory** — one global bandwidth pool `B = peak_compute / R_B`.
//!   Each block demands `c_b / R_b` bytes/ms; bandwidth is allocated
//!   **max-min fairly** (water-filling), and a block's progress rate is
//!   `min(compute share, granted bandwidth × R_b)`. Co-scheduling only
//!   memory-bound kernels oversubscribes the pool and collapses progress;
//!   mixing in compute-bound kernels (combined ratio near `R_B`) does not
//!   — the paper's balance argument.
//! * **Events** — rates are piecewise constant between block completions;
//!   at each event the engine advances time to the earliest projected
//!   finish, retires finished blocks, refills from the queue, and
//!   recomputes rates.
//!
//! # Reusable state and prefix checkpoints
//!
//! The engine is a [`SimState`]: per-kernel constants, the jittered
//! per-block work table, and every scratch buffer are built once by
//! [`SimState::new`] and reused across runs via [`SimState::reset`] — the
//! permutation sweeps evaluate millions of orders on one state with no
//! per-order heap allocation after warm-up.
//!
//! On top of that, [`SimState::push_prefix_kernel`] /
//! [`SimState::finish_with`] expose **prefix checkpointing**: pushing a
//! kernel advances the simulation exactly until that kernel's last block
//! has been dispatched and snapshots the full fluid state at that instant.
//! Because dispatch is strictly in launch order, everything up to that
//! moment is independent of the suffix, so two orders sharing a prefix
//! share the checkpoint — restoring is a buffer copy instead of a
//! re-simulation, and the result is *bit-identical* to simulating the
//! full order from scratch (pinned by tests here and in
//! `tests/sweep_equivalence.rs`).

use crate::gpu::{GpuSpec, KernelProfile, ResourceVec};

/// Simulation failure modes (returned by [`super::validate_workload`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// Kernel has a zero-size grid.
    EmptyKernel { kernel: usize },
    /// Kernel has non-positive per-block work.
    NonPositiveWork { kernel: usize },
    /// A single block exceeds SM capacity: the dispatcher would deadlock.
    BlockNeverFits { kernel: usize },
    /// `order` is not a permutation of `0..kernels.len()`.
    BadOrder,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::EmptyKernel { kernel } => write!(f, "kernel {kernel} has an empty grid"),
            SimError::NonPositiveWork { kernel } => {
                write!(f, "kernel {kernel} has non-positive work per block")
            }
            SimError::BlockNeverFits { kernel } => {
                write!(f, "kernel {kernel} has a block larger than one SM")
            }
            SimError::BadOrder => write!(f, "order is not a permutation of the kernel set"),
        }
    }
}

impl std::error::Error for SimError {}

/// One traced simulator event (only recorded by [`simulate_order_traced`]).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockEvent {
    pub t_ms: f64,
    pub kernel: usize,
    pub sm: u32,
    pub kind: BlockEventKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockEventKind {
    Placed,
    Finished,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Total time until the last block completes (the paper's
    /// "GPU execution time").
    pub makespan_ms: f64,
    /// Completion time of each kernel, indexed like the *input* kernel
    /// slice (not the order).
    pub kernel_finish_ms: Vec<f64>,
    /// Number of completion events processed.
    pub n_events: usize,
    /// Times the dispatcher hit head-of-line blocking with free SM slots
    /// elsewhere in the machine.
    pub dispatch_stalls: usize,
    /// Time-weighted mean of resident warps / total warp capacity.
    pub avg_warp_occupancy: f64,
    /// Optional event trace.
    pub trace: Vec<BlockEvent>,
}

#[derive(Debug, Clone)]
struct Block {
    kernel: u32,
    sm: u32,
    rem_work: f64,
}

/// Per-kernel constants hoisted out of the hot loop.
#[derive(Debug, Clone)]
struct KernelConsts {
    res: ResourceVec,
    /// bytes of memory traffic per unit of compute work (1/R_i); 0 for
    /// pure-compute kernels.
    mem_per_work: f64,
    warps: f64,
}

/// Deterministic per-block execution-time factor in `1 ± jitter`
/// (SplitMix64 finalizer over the block index within its kernel).
///
/// Depends on the block index only — NOT on the kernel — so two identical
/// kernels present exactly the same block multiset and the paper's scope
/// property (identical kernels ⇒ order-invariant makespan) holds exactly.
#[inline]
fn block_jitter_factor(jitter: f64, block: u64) -> f64 {
    if jitter == 0.0 {
        return 1.0;
    }
    let mut z = block.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let u = (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64); // [0,1)
    1.0 + jitter * (2.0 * u - 1.0)
}

/// Simulate the given launch `order` (a permutation of kernel indices).
///
/// Call [`super::validate_workload`] first; this function `debug_assert`s
/// validity and produces meaningless results on invalid input in release
/// builds (it is the innermost loop of the permutation sweeps).
///
/// This is a convenience wrapper that builds a fresh [`SimState`] per
/// call; hot paths that evaluate many orders of one workload should hold
/// a `SimState` and call [`SimState::makespan_of`] instead.
pub fn simulate_order(gpu: &GpuSpec, kernels: &[KernelProfile], order: &[usize]) -> SimResult {
    SimState::new(gpu, kernels).run(order, false)
}

/// As [`simulate_order`], but records a full placement/completion trace.
pub fn simulate_order_traced(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    order: &[usize],
) -> SimResult {
    SimState::new(gpu, kernels).run(order, true)
}

/// A saved copy of the mutable fluid state, taken at the instant the last
/// block of a prefix was dispatched. Buffers are reused across saves.
#[derive(Debug, Clone, Default)]
struct Snapshot {
    t: f64,
    n_events: usize,
    dispatch_stalls: usize,
    occupancy_integral: f64,
    order: Vec<usize>,
    order_pos: usize,
    block_pos: usize,
    sm_used: Vec<ResourceVec>,
    resident: Vec<Block>,
    blocks_left: Vec<u32>,
    kernel_finish: Vec<f64>,
}

/// Reusable fluid-simulation state for one `(gpu, kernels)` workload.
///
/// Construction hoists everything order-independent out of the hot loop:
/// per-kernel resource/rate constants, the jittered per-block work table,
/// and all scratch buffers. Evaluating an order then performs **no heap
/// allocation after warm-up** (asserted by `tests/zero_alloc.rs`).
///
/// Two evaluation paths:
///
/// * [`SimState::makespan_of`] — reset + full-order run (the flat path).
/// * [`SimState::push_prefix_kernel`] / [`SimState::finish_with`] — the
///   prefix-checkpoint path used by the permutation sweeps: state is
///   snapshotted per prefix kernel and restored instead of re-simulated.
#[derive(Debug)]
pub struct SimState {
    // ---- machine constants (copied from GpuSpec) ----
    n_sm: usize,
    sm_cap: ResourceVec,
    blocks_per_sm: usize,
    compute_rate_per_sm: f64,
    bandwidth: f64,
    warp_capacity: f64,
    saturate: f64,
    // ---- per-kernel constants ----
    consts: Vec<KernelConsts>,
    blocks_total: Vec<u32>,
    /// `works[work_offsets[k] + b]` = jittered work of block `b` of kernel
    /// `k` (kernel-major, precomputed once).
    work_offsets: Vec<usize>,
    works: Vec<f64>,
    // ---- per-kernel lower-bound constants (see suffix_lower_bound) ----
    /// Total jittered compute work of each kernel's grid.
    bound_work: Vec<f64>,
    /// Total memory traffic of each kernel's grid (jittered work × 1/R).
    bound_mem: Vec<f64>,
    /// Occupancy-capped aggregate progress rate of each kernel running
    /// alone: `n_sm · C · min(1, m_max · w / warps_to_saturate)` where
    /// `m_max` is the kernel's solo blocks-per-SM occupancy limit.
    bound_occ_rate: Vec<f64>,
    /// Fastest possible single-block completion: the heaviest block's
    /// work over the best per-block rate `C · w / max(w, saturate)`.
    bound_block_floor: Vec<f64>,
    // ---- mutable fluid state ----
    t: f64,
    n_events: usize,
    dispatch_stalls: usize,
    occupancy_integral: f64,
    sm_used: Vec<ResourceVec>,
    resident: Vec<Block>,
    blocks_left: Vec<u32>,
    kernel_finish: Vec<f64>,
    /// The order being executed: the checkpointed prefix plus any suffix.
    order_buf: Vec<usize>,
    /// Dispatch cursor: next block is `(order_buf[order_pos], block_pos)`.
    order_pos: usize,
    block_pos: usize,
    // ---- event-loop scratch (reused, zero alloc per event) ----
    rates: Vec<f64>,
    demands: Vec<f64>,
    sorted_scratch: Vec<f64>,
    /// Per-SM resident-warp totals, sized from `GpuSpec::n_sm` (replaces
    /// the old fixed `[0.0; 64]` array that silently produced garbage for
    /// `n_sm > 64` machines in release builds).
    sm_warps: Vec<f64>,
    // ---- tracing ----
    traced: bool,
    trace: Vec<BlockEvent>,
    // ---- prefix checkpoints ----
    /// `snapshots[d]` is the state with the first `d` prefix kernels fully
    /// dispatched; `snapshots[0]` is the pristine reset state.
    snapshots: Vec<Snapshot>,
    depth: usize,
}

impl SimState {
    /// Build reusable state for one workload. Does not validate — call
    /// [`super::validate_workload`] first (an unsimulable workload would
    /// deadlock the in-order dispatcher).
    pub fn new(gpu: &GpuSpec, kernels: &[KernelProfile]) -> SimState {
        let consts: Vec<KernelConsts> = kernels
            .iter()
            .map(|k| KernelConsts {
                res: k.block_resources(),
                mem_per_work: if k.ratio > 0.0 { 1.0 / k.ratio } else { 0.0 },
                warps: k.warps_per_block as f64,
            })
            .collect();
        let blocks_total: Vec<u32> = kernels.iter().map(|k| k.n_blocks).collect();

        // Jittered per-block work table, kernel-major. The jitter factor
        // depends only on the block index within its kernel — never on the
        // order — so every permutation sees the same physical workload.
        let total_blocks: usize = blocks_total.iter().map(|&b| b as usize).sum();
        let mut work_offsets = Vec::with_capacity(kernels.len() + 1);
        let mut works = Vec::with_capacity(total_blocks);
        work_offsets.push(0);
        for k in kernels {
            for b in 0..k.n_blocks {
                works.push(k.work_per_block * block_jitter_factor(gpu.block_jitter, b as u64));
            }
            work_offsets.push(works.len());
        }

        // Admissible-bound constants: everything here must *under*-state
        // how fast the fluid model can retire work (see
        // [`SimState::suffix_lower_bound`] for the admissibility proofs).
        let saturate = gpu.warps_to_saturate as f64;
        let peak_per_sm = gpu.compute_rate_per_sm;
        let mut bound_work = Vec::with_capacity(kernels.len());
        let mut bound_mem = Vec::with_capacity(kernels.len());
        let mut bound_occ_rate = Vec::with_capacity(kernels.len());
        let mut bound_block_floor = Vec::with_capacity(kernels.len());
        for (k, prof) in kernels.iter().enumerate() {
            let blocks = &works[work_offsets[k]..work_offsets[k + 1]];
            let total: f64 = blocks.iter().sum();
            let heaviest = blocks.iter().copied().fold(0.0f64, f64::max);
            bound_work.push(total);
            bound_mem.push(total * consts[k].mem_per_work);
            let w = prof.warps_per_block as f64;
            if w > 0.0 {
                let m_max = prof.max_blocks_per_sm(gpu) as f64;
                let phi = (m_max * w / saturate).min(1.0);
                bound_occ_rate.push((gpu.n_sm as f64 * peak_per_sm * phi).max(f64::MIN_POSITIVE));
                bound_block_floor.push(heaviest * w.max(saturate) / (peak_per_sm * w));
            } else {
                // Degenerate zero-warp kernel: claim nothing beyond the
                // aggregate peak (weak but still admissible).
                bound_occ_rate.push(gpu.n_sm as f64 * peak_per_sm);
                bound_block_floor.push(0.0);
            }
        }

        let n = kernels.len();
        let n_sm = gpu.n_sm as usize;
        let resident_cap = n_sm * gpu.blocks_per_sm as usize;
        let mut state = SimState {
            n_sm,
            sm_cap: gpu.sm_capacity(),
            blocks_per_sm: gpu.blocks_per_sm as usize,
            compute_rate_per_sm: gpu.compute_rate_per_sm,
            bandwidth: gpu.memory_bandwidth(),
            warp_capacity: (gpu.warps_per_sm * gpu.n_sm) as f64,
            saturate: gpu.warps_to_saturate as f64,
            consts,
            blocks_total,
            work_offsets,
            works,
            bound_work,
            bound_mem,
            bound_occ_rate,
            bound_block_floor,
            t: 0.0,
            n_events: 0,
            dispatch_stalls: 0,
            occupancy_integral: 0.0,
            sm_used: vec![ResourceVec::ZERO; n_sm],
            resident: Vec::with_capacity(resident_cap),
            blocks_left: vec![0; n],
            kernel_finish: vec![0.0; n],
            order_buf: Vec::with_capacity(n),
            order_pos: 0,
            block_pos: 0,
            rates: Vec::with_capacity(resident_cap),
            demands: Vec::with_capacity(resident_cap),
            sorted_scratch: Vec::with_capacity(resident_cap),
            sm_warps: vec![0.0; n_sm],
            traced: false,
            trace: Vec::new(),
            snapshots: Vec::with_capacity(n + 1),
            depth: 0,
        };
        state.reset();
        state.save_snapshot(); // snapshots[0] = pristine state
        state
    }

    /// Number of kernels in the prepared workload.
    pub fn n_kernels(&self) -> usize {
        self.consts.len()
    }

    /// Length of the currently checkpointed prefix.
    pub fn prefix_len(&self) -> usize {
        self.depth.saturating_sub(1)
    }

    /// Clear the mutable fluid state back to `t = 0` with an empty order.
    /// Checkpoints are untouched (`snapshots[0]` *is* this state).
    pub fn reset(&mut self) {
        self.t = 0.0;
        self.n_events = 0;
        self.dispatch_stalls = 0;
        self.occupancy_integral = 0.0;
        for s in &mut self.sm_used {
            *s = ResourceVec::ZERO;
        }
        self.resident.clear();
        self.blocks_left.copy_from_slice(&self.blocks_total);
        self.kernel_finish.fill(0.0);
        self.order_buf.clear();
        self.order_pos = 0;
        self.block_pos = 0;
        self.trace.clear();
    }

    /// Makespan of one complete launch `order` (a permutation of
    /// `0..n_kernels()`), evaluated on the flat path: reset, run to
    /// completion. Allocation-free after warm-up.
    pub fn makespan_of(&mut self, order: &[usize]) -> f64 {
        self.debug_check_permutation(order);
        self.reset();
        self.order_buf.extend_from_slice(order);
        self.run_to_completion();
        self.t
    }

    /// Full-result evaluation of one order (allocates the result vectors;
    /// use [`SimState::makespan_of`] on hot paths).
    pub fn run(&mut self, order: &[usize], traced: bool) -> SimResult {
        self.traced = traced;
        let makespan_ms = self.makespan_of(order);
        self.traced = false;
        SimResult {
            makespan_ms,
            kernel_finish_ms: self.kernel_finish.clone(),
            n_events: self.n_events,
            dispatch_stalls: self.dispatch_stalls,
            avg_warp_occupancy: if self.t > 0.0 {
                self.occupancy_integral / self.t
            } else {
                0.0
            },
            trace: std::mem::take(&mut self.trace),
        }
    }

    /// Extend the checkpointed prefix with kernel `k`: restore the current
    /// prefix's snapshot, advance the simulation exactly until `k`'s last
    /// block has been dispatched, and snapshot that instant.
    ///
    /// Dispatch is strictly in launch order, so everything simulated here
    /// is independent of any future suffix — continuing from the snapshot
    /// is bit-identical to simulating the full order from scratch.
    pub fn push_prefix_kernel(&mut self, k: usize) {
        debug_assert!(!self.traced, "checkpointing does not snapshot traces");
        debug_assert!(k < self.consts.len());
        debug_assert!(!self.order_in_snapshot_contains(k));
        self.restore_top();
        self.order_buf.push(k);
        let limit = self.order_buf.len();
        while !self.dispatch_up_to(limit) {
            debug_assert!(!self.resident.is_empty(), "dispatcher deadlocked");
            self.advance_event();
        }
        self.save_snapshot();
    }

    /// Drop the most recent prefix kernel's checkpoint.
    pub fn pop_prefix_kernel(&mut self) {
        debug_assert!(self.depth > 1, "no prefix kernel to pop");
        self.depth -= 1;
    }

    /// Complete the checkpointed prefix with `suffix` (the remaining
    /// kernels, possibly empty) and return the makespan. The checkpoint
    /// stack is left intact, so this can be called once per sibling
    /// suffix. Allocation-free after warm-up.
    pub fn finish_with(&mut self, suffix: &[usize]) -> f64 {
        debug_assert!(!self.traced, "checkpointing does not snapshot traces");
        self.restore_top();
        self.order_buf.extend_from_slice(suffix);
        self.run_to_completion();
        self.t
    }

    /// [`SimState::finish_with`] generalized to any stack level: complete
    /// the prefix checkpointed at `depth` (`0` = the empty prefix, up to
    /// [`SimState::prefix_len`]) with `suffix`, leaving the whole stack —
    /// including the checkpoints above `depth` — intact. Snapshots are
    /// pure functions of their prefix, so the result is bit-identical to
    /// [`SimState::makespan_of`] on `prefix[..depth] ++ suffix` no matter
    /// what was evaluated in between. This is the depth-addressable seam
    /// behind [`crate::exec::PrefixCursor`]. Allocation-free after
    /// warm-up.
    pub fn finish_from(&mut self, depth: usize, suffix: &[usize]) -> f64 {
        debug_assert!(!self.traced, "checkpointing does not snapshot traces");
        debug_assert!(depth < self.depth, "no checkpoint at depth {depth}");
        self.restore_at(depth);
        self.order_buf.extend_from_slice(suffix);
        self.run_to_completion();
        self.t
    }

    /// Admissible lower bound on [`SimState::finish_with`] over **every**
    /// permutation of `remaining` — the branch-and-bound pruning bound.
    ///
    /// Reads the top checkpoint (taken at time `t₀`, the instant the
    /// prefix's last block was dispatched) without touching the working
    /// state, and combines three fluid-model invariants, each of which no
    /// completion order can beat:
    ///
    /// * **Aggregate work** — residual compute work (leftover work of
    ///   resident prefix blocks + the whole grids of `remaining`) drains
    ///   at ≤ `n_sm · C` GPU-wide, because each SM's processor-sharing
    ///   rates sum to `C · warps / max(warps, saturate) ≤ C`.
    /// * **Aggregate memory** — residual traffic drains at ≤ the global
    ///   bandwidth pool `B` (max-min fair allocation never over-grants).
    /// * **Per-kernel occupancy** — a remaining kernel `k` dispatches no
    ///   earlier than `t₀` (dispatch is strictly in launch order), and its
    ///   own grid progresses at ≤ `n_sm · C · min(1, m_max·w/saturate)`
    ///   (its solo occupancy cap; co-residents only slow it down), nor can
    ///   it finish before its heaviest single block runs at the best
    ///   per-block rate `C · w / max(w, saturate)`.
    ///
    /// Allocation-free and `O(resident + remaining)`.
    pub fn suffix_lower_bound(&self, remaining: &[usize]) -> f64 {
        let snap = &self.snapshots[self.depth.saturating_sub(1)];
        let t0 = snap.t;
        let mut work_rem = 0.0f64;
        let mut mem_rem = 0.0f64;
        for b in &snap.resident {
            let kc = &self.consts[b.kernel as usize];
            work_rem += b.rem_work;
            mem_rem += b.rem_work * kc.mem_per_work;
        }
        let mut per_kernel = 0.0f64;
        for &k in remaining {
            work_rem += self.bound_work[k];
            mem_rem += self.bound_mem[k];
            let solo =
                (self.bound_work[k] / self.bound_occ_rate[k]).max(self.bound_block_floor[k]);
            per_kernel = per_kernel.max(solo);
        }
        let peak = self.compute_rate_per_sm * self.n_sm as f64;
        let aggregate = (work_rem / peak).max(mem_rem / self.bandwidth);
        t0 + aggregate.max(per_kernel)
    }

    // ---- internals -------------------------------------------------------

    /// Alloc-free O(n²) permutation check (debug builds only).
    fn debug_check_permutation(&self, order: &[usize]) {
        debug_assert_eq!(order.len(), self.consts.len());
        debug_assert!(order.iter().all(|&k| k < self.consts.len()));
        debug_assert!(order
            .iter()
            .enumerate()
            .all(|(i, &a)| order[i + 1..].iter().all(|&b| a != b)));
    }

    fn order_in_snapshot_contains(&self, k: usize) -> bool {
        self.depth > 0 && self.snapshots[self.depth - 1].order.contains(&k)
    }

    fn save_snapshot(&mut self) {
        if self.snapshots.len() == self.depth {
            // Reserve every buffer at its workload-wide maximum up front,
            // so saving a *different* prefix at this depth later (the
            // anytime cursor re-anchors constantly) can never reallocate
            // — first touch of a depth is the only allocation.
            let n = self.consts.len();
            self.snapshots.push(Snapshot {
                order: Vec::with_capacity(n),
                sm_used: Vec::with_capacity(self.n_sm),
                resident: Vec::with_capacity(self.n_sm * self.blocks_per_sm),
                blocks_left: Vec::with_capacity(n),
                kernel_finish: Vec::with_capacity(n),
                ..Snapshot::default()
            });
        }
        let snap = &mut self.snapshots[self.depth];
        snap.t = self.t;
        snap.n_events = self.n_events;
        snap.dispatch_stalls = self.dispatch_stalls;
        snap.occupancy_integral = self.occupancy_integral;
        snap.order_pos = self.order_pos;
        snap.block_pos = self.block_pos;
        snap.order.clear();
        snap.order.extend_from_slice(&self.order_buf);
        snap.sm_used.clear();
        snap.sm_used.extend_from_slice(&self.sm_used);
        snap.resident.clear();
        snap.resident.extend_from_slice(&self.resident);
        snap.blocks_left.clear();
        snap.blocks_left.extend_from_slice(&self.blocks_left);
        snap.kernel_finish.clear();
        snap.kernel_finish.extend_from_slice(&self.kernel_finish);
        self.depth += 1;
    }

    fn restore_top(&mut self) {
        debug_assert!(self.depth > 0);
        self.restore_at(self.depth - 1);
    }

    fn restore_at(&mut self, idx: usize) {
        let snap = &self.snapshots[idx];
        self.t = snap.t;
        self.n_events = snap.n_events;
        self.dispatch_stalls = snap.dispatch_stalls;
        self.occupancy_integral = snap.occupancy_integral;
        self.order_pos = snap.order_pos;
        self.block_pos = snap.block_pos;
        self.order_buf.clear();
        self.order_buf.extend_from_slice(&snap.order);
        self.sm_used.clear();
        self.sm_used.extend_from_slice(&snap.sm_used);
        self.resident.clear();
        self.resident.extend_from_slice(&snap.resident);
        self.blocks_left.clear();
        self.blocks_left.extend_from_slice(&snap.blocks_left);
        self.kernel_finish.clear();
        self.kernel_finish.extend_from_slice(&snap.kernel_finish);
    }

    /// Place head blocks in order while they fit, considering only the
    /// first `limit` kernels of `order_buf`. Returns `true` once every
    /// block of those kernels has been dispatched, `false` on a
    /// head-of-line stall (head block fits nowhere right now).
    fn dispatch_up_to(&mut self, limit: usize) -> bool {
        while self.order_pos < limit {
            let ki = self.order_buf[self.order_pos];
            if self.block_pos >= self.blocks_total[ki] as usize {
                self.order_pos += 1;
                self.block_pos = 0;
                continue;
            }
            let need = self.consts[ki].res;
            // Least-loaded-by-warps SM that fits; ties to lowest index.
            let mut best: Option<usize> = None;
            for s in 0..self.n_sm {
                if (self.sm_used[s] + need).fits_within(&self.sm_cap) {
                    match best {
                        None => best = Some(s),
                        Some(b) if self.sm_used[s].warps < self.sm_used[b].warps => {
                            best = Some(s)
                        }
                        _ => {}
                    }
                }
            }
            let Some(s) = best else {
                if self.resident.len() < self.n_sm * self.blocks_per_sm {
                    self.dispatch_stalls += 1;
                }
                return false;
            };
            self.sm_used[s] += need;
            self.resident.push(Block {
                kernel: ki as u32,
                sm: s as u32,
                rem_work: self.works[self.work_offsets[ki] + self.block_pos],
            });
            if self.traced {
                self.trace.push(BlockEvent {
                    t_ms: self.t,
                    kernel: ki,
                    sm: s as u32,
                    kind: BlockEventKind::Placed,
                });
            }
            self.block_pos += 1;
        }
        true
    }

    /// Compute rates (processor-sharing compute + max-min-fair memory),
    /// advance time to the earliest completion, retire finished blocks.
    fn advance_event(&mut self) {
        // ---- rates: processor-sharing compute + max-min-fair memory ----
        self.rates.clear();
        self.rates.reserve(self.resident.len());
        // Per-SM warp totals (reusable scratch sized from GpuSpec).
        self.sm_warps.fill(0.0);
        for b in &self.resident {
            self.sm_warps[b.sm as usize] += self.consts[b.kernel as usize].warps;
        }
        let resident_warps: f64 = self.sm_warps.iter().sum();
        for b in &self.resident {
            let kc = &self.consts[b.kernel as usize];
            let denom = self.sm_warps[b.sm as usize].max(self.saturate);
            self.rates.push(self.compute_rate_per_sm * kc.warps / denom);
        }

        // Max-min fair bandwidth: find the water level L with
        // sum(min(d_b, L)) = B, then p_b = min(c_b, grant_b * R_b).
        self.demands.clear();
        self.demands.reserve(self.resident.len());
        let mut total_demand = 0.0;
        for (i, b) in self.resident.iter().enumerate() {
            let d = self.rates[i] * self.consts[b.kernel as usize].mem_per_work;
            self.demands.push(d);
            total_demand += d;
        }
        if total_demand > self.bandwidth {
            // Water-filling over the sorted demands (reused scratch).
            self.sorted_scratch.clear();
            self.sorted_scratch.extend_from_slice(&self.demands);
            self.sorted_scratch
                .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            let mut rem = self.bandwidth;
            let mut level = f64::INFINITY;
            let mut m = self.sorted_scratch.len();
            for d in &self.sorted_scratch {
                let fair = rem / m as f64;
                if *d <= fair {
                    rem -= d;
                    m -= 1;
                } else {
                    level = fair;
                    break;
                }
            }
            for (i, b) in self.resident.iter().enumerate() {
                let kc = &self.consts[b.kernel as usize];
                if self.demands[i] > level && kc.mem_per_work > 0.0 {
                    // Memory-throttled: granted `level` bytes/ms.
                    self.rates[i] = self.rates[i].min(level / kc.mem_per_work);
                }
            }
        }

        // ---- advance to earliest completion ----
        let mut dt = f64::INFINITY;
        for (i, b) in self.resident.iter().enumerate() {
            let ti = b.rem_work / self.rates[i];
            if ti < dt {
                dt = ti;
            }
        }
        debug_assert!(dt.is_finite() && dt > 0.0);
        self.t += dt;
        self.occupancy_integral += resident_warps / self.warp_capacity * dt;
        self.n_events += 1;

        // Retire finished blocks (everything within float noise of done).
        let eps = dt * 1e-9;
        let mut i = 0;
        while i < self.resident.len() {
            let finished = {
                let b = &mut self.resident[i];
                b.rem_work -= self.rates[i] * dt;
                b.rem_work <= self.rates[i] * eps
            };
            if finished {
                let b = self.resident.swap_remove(i);
                self.rates.swap_remove(i);
                self.sm_used[b.sm as usize] -= self.consts[b.kernel as usize].res;
                debug_assert!(self.sm_used[b.sm as usize].non_negative());
                let k = b.kernel as usize;
                self.blocks_left[k] -= 1;
                if self.blocks_left[k] == 0 {
                    self.kernel_finish[k] = self.t;
                }
                if self.traced {
                    self.trace.push(BlockEvent {
                        t_ms: self.t,
                        kernel: k,
                        sm: b.sm,
                        kind: BlockEventKind::Finished,
                    });
                }
            } else {
                i += 1;
            }
        }
    }

    fn run_to_completion(&mut self) {
        loop {
            self.dispatch_up_to(self.order_buf.len());
            if self.resident.is_empty() {
                debug_assert_eq!(
                    self.order_pos,
                    self.order_buf.len(),
                    "dispatcher deadlocked"
                );
                break;
            }
            self.advance_event();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::kernel;
    use super::*;
    use crate::gpu::GpuSpec;

    /// Deterministic test GPU with a low saturation point so the exact
    /// arithmetic below is easy to verify by hand.
    fn tgpu() -> GpuSpec {
        let mut g = GpuSpec::gtx580().deterministic();
        g.warps_to_saturate = 16;
        g
    }

    #[test]
    fn single_kernel_single_block_time() {
        let gpu = tgpu();
        // One block, 16 warps (saturating), pure compute (huge ratio).
        let ks = vec![kernel("k", 1, 16, 0, 1e9, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        // Saturated single block: rate = compute_rate_per_sm.
        assert!((r.makespan_ms - 1.0).abs() < 1e-9, "{}", r.makespan_ms);
        assert_eq!(r.n_events, 1);
    }

    #[test]
    fn undersaturated_block_runs_slower() {
        let gpu = tgpu();
        // 4 warps < warps_to_saturate=16 -> rate = C * 4/16.
        let ks = vec![kernel("k", 1, 4, 0, 1e9, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        assert!((r.makespan_ms - 4.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn jitter_spreads_block_times() {
        let mut gpu = tgpu();
        gpu.block_jitter = 0.10;
        // Two blocks of the same kernel on different SMs: their finish
        // times differ by the jitter factors but stay within ±10%.
        let ks = vec![kernel("k", 2, 16, 0, 1e9, 1000.0)];
        let r = simulate_order_traced(&gpu, &ks, &[0]);
        let finishes: Vec<f64> = r
            .trace
            .iter()
            .filter(|e| e.kind == BlockEventKind::Finished)
            .map(|e| e.t_ms)
            .collect();
        assert_eq!(finishes.len(), 2);
        for t in &finishes {
            assert!((0.9..=1.1).contains(t), "{t}");
        }
        assert!((finishes[0] - finishes[1]).abs() > 1e-6);
    }

    #[test]
    fn two_identical_blocks_one_sm_share_compute() {
        // Force both blocks onto one SM: a 1-SM GPU variant.
        let mut gpu1 = tgpu();
        gpu1.n_sm = 1;
        let ks = vec![kernel("k", 2, 16, 0, 1e9, 1000.0)];
        let r = simulate_order(&gpu1, &ks, &[0]);
        // 32 resident warps, each block gets C/2 -> both finish at 2 ms.
        assert!((r.makespan_ms - 2.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn blocks_spread_across_sms() {
        let gpu = tgpu();
        // 16 blocks on 16 SMs: each alone, saturating -> 1 ms total.
        let ks = vec![kernel("k", 16, 16, 0, 1e9, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        assert!((r.makespan_ms - 1.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn large_sm_count_supported() {
        // Regression for the old fixed `[0.0; 64]` per-SM scratch array:
        // a machine with more than 64 SMs must simulate correctly (the
        // scratch is now sized from GpuSpec).
        let mut gpu = tgpu();
        gpu.n_sm = 100;
        // 100 saturating blocks on 100 SMs: each alone -> 1 ms total.
        let ks = vec![kernel("k", 100, 16, 0, 1e9, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        assert!((r.makespan_ms - 1.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn memory_bound_kernel_is_bandwidth_limited() {
        let gpu = tgpu();
        // Fill the GPU with saturating, very memory-bound blocks (R = 1
        // << R_B = 4.11). 16 blocks x 16 warps, work 1000 each.
        let ks = vec![kernel("k", 16, 16, 0, 1.0, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        // Total mem = 16 * 1000 / 1.0 = 16000 bytes; B = 16000/4.11 -> t =
        // 16000/(16*1000/4.11) = 4.11 ms (bandwidth-limited).
        assert!((r.makespan_ms - 4.11).abs() < 1e-6, "{}", r.makespan_ms);
    }

    #[test]
    fn balanced_kernel_hits_lower_bound() {
        let gpu = tgpu();
        let ks = vec![kernel("k", 16, 16, 0, gpu.balanced_ratio, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        let lb = gpu.makespan_lower_bound(ks[0].total_work(), ks[0].total_mem());
        assert!((r.makespan_ms - lb).abs() < 1e-6);
    }

    #[test]
    fn mixing_compute_and_memory_bound_beats_segregation() {
        // The paper's core claim: co-residency of opposing kernel types
        // outperforms same-type clustering. Build 2 memory-bound + 2
        // compute-bound kernels, each sized at half the SM warp budget so
        // exactly two kernels co-reside per round.
        let gpu = tgpu();
        let mem = || kernel("mem", 16, 24, 0, 1.0, 3000.0);
        let cmp = || kernel("cmp", 16, 24, 0, 1e9, 3000.0);
        let ks = vec![mem(), mem(), cmp(), cmp()];
        let segregated = simulate_order(&gpu, &ks, &[0, 1, 2, 3]).makespan_ms;
        let interleaved = simulate_order(&gpu, &ks, &[0, 2, 1, 3]).makespan_ms;
        assert!(
            interleaved < segregated * 0.999,
            "interleaved {interleaved} !< segregated {segregated}"
        );
    }

    #[test]
    fn head_of_line_blocking_penalizes_bad_order() {
        // A shared-memory hog (48K/block) blocks everything behind it on
        // the same SM; launching hogs first then small kernels lets the
        // small ones pack around them, while alternating strands capacity.
        let gpu = tgpu();
        let hog = || kernel("hog", 16, 4, 48 * 1024, 1e9, 4000.0);
        let tiny = || kernel("tiny", 16, 4, 0, 1e9, 1000.0);
        let ks = vec![hog(), hog(), tiny(), tiny()];
        let good = simulate_order(&gpu, &ks, &[0, 2, 1, 3]).makespan_ms;
        let bad = simulate_order(&gpu, &ks, &[0, 1, 2, 3]).makespan_ms;
        assert!(good <= bad, "good {good} > bad {bad}");
    }

    #[test]
    fn identical_kernels_order_invariant() {
        // Paper, Scope & Applicability: identical kernels differing only
        // in block count -> order does not matter. Holds with jitter ON
        // because the jitter factor depends only on the block index.
        let gpu = GpuSpec::gtx580();
        assert!(gpu.block_jitter > 0.0);
        let ks = vec![
            kernel("a", 8, 8, 4096, 3.0, 500.0),
            kernel("b", 24, 8, 4096, 3.0, 500.0),
            kernel("c", 16, 8, 4096, 3.0, 500.0),
        ];
        let t0 = simulate_order(&gpu, &ks, &[0, 1, 2]).makespan_ms;
        for order in [[0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]] {
            let t = simulate_order(&gpu, &ks, &order).makespan_ms;
            assert!(
                (t - t0).abs() < 1e-6 * t0,
                "order {order:?}: {t} vs {t0}"
            );
        }
    }

    #[test]
    fn all_blocks_complete_and_finish_times_recorded() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 20, 8, 8192, 2.0, 700.0),
            kernel("b", 40, 12, 0, 9.0, 300.0),
        ];
        let r = simulate_order(&gpu, &ks, &[1, 0]);
        assert!(r.n_events >= 1);
        for (i, &f) in r.kernel_finish_ms.iter().enumerate() {
            assert!(f > 0.0, "kernel {i} never finished");
            assert!(f <= r.makespan_ms + 1e-12);
        }
        assert!((r.kernel_finish_ms.iter().cloned().fold(0.0, f64::max)
            - r.makespan_ms)
            .abs()
            < 1e-12);
    }

    #[test]
    fn trace_is_balanced_and_ordered() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 10, 8, 0, 3.0, 500.0),
            kernel("b", 10, 8, 0, 9.0, 500.0),
        ];
        let r = simulate_order_traced(&gpu, &ks, &[0, 1]);
        let placed = r.trace.iter().filter(|e| e.kind == BlockEventKind::Placed).count();
        let finished = r.trace.iter().filter(|e| e.kind == BlockEventKind::Finished).count();
        assert_eq!(placed, 20);
        assert_eq!(finished, 20);
        // Timestamps non-decreasing.
        for w in r.trace.windows(2) {
            assert!(w[0].t_ms <= w[1].t_ms + 1e-12);
        }
    }

    #[test]
    fn makespan_never_beats_lower_bound() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 16, 4, 8192, 3.11, 800.0),
            kernel("b", 32, 8, 0, 11.1, 400.0),
            kernel("c", 48, 6, 16384, 2.0, 300.0),
        ];
        let total_work: f64 = ks.iter().map(|k| k.total_work()).sum();
        let total_mem: f64 = ks.iter().map(|k| k.total_mem()).sum();
        let lb = gpu.makespan_lower_bound(total_work, total_mem);
        for order in [[0, 1, 2], [2, 1, 0], [1, 0, 2]] {
            let r = simulate_order(&gpu, &ks, &order);
            assert!(r.makespan_ms >= lb * (1.0 - 1e-9));
        }
    }

    #[test]
    fn occupancy_fraction_sane() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel("a", 64, 8, 0, 4.0, 500.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        assert!(r.avg_warp_occupancy > 0.0 && r.avg_warp_occupancy <= 1.0);
    }

    // ---- SimState reuse + checkpointing --------------------------------

    #[test]
    fn reused_state_matches_fresh_state_bitwise() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 16, 4, 8192, 3.11, 800.0),
            kernel("b", 32, 8, 0, 11.1, 400.0),
            kernel("c", 48, 6, 16384, 2.0, 300.0),
            kernel("d", 12, 16, 0, 1.0, 600.0),
        ];
        let mut state = SimState::new(&gpu, &ks);
        for order in [[0, 1, 2, 3], [3, 2, 1, 0], [1, 3, 0, 2], [2, 0, 3, 1]] {
            let reused = state.makespan_of(&order);
            let fresh = simulate_order(&gpu, &ks, &order).makespan_ms;
            assert_eq!(reused.to_bits(), fresh.to_bits(), "order {order:?}");
        }
    }

    #[test]
    fn checkpointed_prefixes_match_full_runs_bitwise() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 16, 4, 8192, 3.11, 800.0),
            kernel("b", 32, 8, 0, 11.1, 400.0),
            kernel("c", 48, 6, 16384, 2.0, 300.0),
            kernel("d", 12, 16, 0, 1.0, 600.0),
        ];
        let mut state = SimState::new(&gpu, &ks);
        // Every 4-kernel order, evaluated as prefix [a, b] + suffix.
        for a in 0..4usize {
            state.push_prefix_kernel(a);
            for b in 0..4usize {
                if b == a {
                    continue;
                }
                state.push_prefix_kernel(b);
                for c in 0..4usize {
                    if c == a || c == b {
                        continue;
                    }
                    let d = 6 - a - b - c;
                    let order = [a, b, c, d];
                    let checkpointed = state.finish_with(&[c, d]);
                    let full = simulate_order(&gpu, &ks, &order).makespan_ms;
                    assert_eq!(
                        checkpointed.to_bits(),
                        full.to_bits(),
                        "order {order:?}: {checkpointed} vs {full}"
                    );
                }
                state.pop_prefix_kernel();
            }
            state.pop_prefix_kernel();
        }
        assert_eq!(state.prefix_len(), 0);
    }

    #[test]
    fn suffix_lower_bound_never_exceeds_any_completion() {
        // Admissibility pin: the branch-and-bound pruning bound must be ≤
        // the makespan of *every* way of completing the prefix. Checked
        // exhaustively over all prefixes of a 5-kernel workload.
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 16, 4, 8192, 3.11, 800.0),
            kernel("b", 32, 8, 0, 11.1, 400.0),
            kernel("c", 48, 6, 16384, 2.0, 300.0),
            kernel("d", 12, 16, 0, 1.0, 600.0),
            kernel("e", 16, 24, 24576, 5.0, 900.0),
        ];
        let n = ks.len();
        let mut state = SimState::new(&gpu, &ks);

        fn check(state: &mut SimState, prefix: &mut Vec<usize>, n: usize) {
            let remaining: Vec<usize> = (0..n).filter(|k| !prefix.contains(k)).collect();
            let lb = state.suffix_lower_bound(&remaining);
            // Every completion of this prefix must respect the bound.
            let mut rest = remaining.clone();
            crate::perm::for_each_permutation(&mut rest, &mut |suffix| {
                let t = state.finish_with(suffix);
                assert!(
                    lb <= t * (1.0 + 1e-9),
                    "prefix {prefix:?} suffix {suffix:?}: bound {lb} > makespan {t}"
                );
            });
            if remaining.is_empty() {
                let t = state.finish_with(&[]);
                assert!(lb <= t * (1.0 + 1e-9));
            }
            for &k in &remaining {
                state.push_prefix_kernel(k);
                prefix.push(k);
                check(state, prefix, n);
                prefix.pop();
                state.pop_prefix_kernel();
            }
        }
        check(&mut state, &mut Vec::new(), n);
    }

    #[test]
    fn finish_from_matches_full_runs_at_every_depth() {
        // The depth-addressable restore must be bit-identical to a flat
        // run of prefix[..depth] ++ suffix, and must leave the deeper
        // checkpoints usable afterwards.
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 16, 4, 8192, 3.11, 800.0),
            kernel("b", 32, 8, 0, 11.1, 400.0),
            kernel("c", 48, 6, 16384, 2.0, 300.0),
            kernel("d", 12, 16, 0, 1.0, 600.0),
        ];
        let mut state = SimState::new(&gpu, &ks);
        let prefix = [2usize, 0, 3];
        for &k in &prefix {
            state.push_prefix_kernel(k);
        }
        // depth 0..=3, each completed with the lexicographically smallest
        // suffix over the unused kernels.
        let suffixes: [&[usize]; 4] = [&[0, 1, 2, 3], &[0, 1, 3], &[1, 3], &[1]];
        for (depth, suffix) in suffixes.iter().enumerate() {
            let mut order: Vec<usize> = prefix[..depth].to_vec();
            order.extend_from_slice(suffix);
            let from = state.finish_from(depth, suffix);
            let full = simulate_order(&gpu, &ks, &order).makespan_ms;
            assert_eq!(from.to_bits(), full.to_bits(), "depth {depth}");
        }
        // The top-of-stack checkpoint survived every mid-stack restore.
        let top = state.finish_with(&[1]);
        let full = simulate_order(&gpu, &ks, &[2, 0, 3, 1]).makespan_ms;
        assert_eq!(top.to_bits(), full.to_bits());
    }

    #[test]
    fn checkpoints_and_flat_runs_interleave_safely() {
        // A flat makespan_of between checkpoint ops must not corrupt the
        // checkpoint stack.
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 16, 4, 0, 3.0, 500.0),
            kernel("b", 24, 8, 0, 9.0, 400.0),
            kernel("c", 8, 12, 8192, 1.5, 700.0),
        ];
        let mut state = SimState::new(&gpu, &ks);
        state.push_prefix_kernel(1);
        let t_flat = state.makespan_of(&[2, 1, 0]);
        assert_eq!(
            t_flat.to_bits(),
            simulate_order(&gpu, &ks, &[2, 1, 0]).makespan_ms.to_bits()
        );
        // Checkpoint for prefix [1] still valid after the flat run.
        let t_ck = state.finish_with(&[0, 2]);
        assert_eq!(
            t_ck.to_bits(),
            simulate_order(&gpu, &ks, &[1, 0, 2]).makespan_ms.to_bits()
        );
        state.pop_prefix_kernel();
    }
}
