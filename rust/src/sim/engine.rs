//! The event-driven fluid simulation engine.
//!
//! Model summary (DESIGN.md §5):
//!
//! * **Dispatch** — the block queue is the concatenation of each kernel's
//!   blocks in launch order. Dispatch is strictly in order: the head block
//!   is placed on the least-loaded SM on which its resource vector fits;
//!   if it fits nowhere, dispatch stalls until a completion frees space
//!   (head-of-line blocking — the mechanism that makes launch order
//!   matter on Fermi-class hardware).
//! * **Compute** — each SM is a processor-sharing server: its issue
//!   throughput (`compute_rate_per_sm`, reached at `warps_to_saturate`
//!   resident warps) is divided among resident blocks in proportion to
//!   their warp counts. Below saturation, throughput scales with resident
//!   warps — this is what rewards co-residency (higher occupancy = more
//!   latency hiding).
//! * **Memory** — one global bandwidth pool `B = peak_compute / R_B`.
//!   Each block demands `c_b / R_b` bytes/ms; bandwidth is allocated
//!   **max-min fairly** (water-filling), and a block's progress rate is
//!   `min(compute share, granted bandwidth × R_b)`. Co-scheduling only
//!   memory-bound kernels oversubscribes the pool and collapses progress;
//!   mixing in compute-bound kernels (combined ratio near `R_B`) does not
//!   — the paper's balance argument.
//! * **Events** — rates are piecewise constant between block completions;
//!   at each event the engine advances time to the earliest projected
//!   finish, retires finished blocks, refills from the queue, and
//!   recomputes rates.

use crate::gpu::{GpuSpec, KernelProfile, ResourceVec};

/// Simulation failure modes (returned by [`super::validate_workload`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// Kernel has a zero-size grid.
    EmptyKernel { kernel: usize },
    /// Kernel has non-positive per-block work.
    NonPositiveWork { kernel: usize },
    /// A single block exceeds SM capacity: the dispatcher would deadlock.
    BlockNeverFits { kernel: usize },
    /// `order` is not a permutation of `0..kernels.len()`.
    BadOrder,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::EmptyKernel { kernel } => write!(f, "kernel {kernel} has an empty grid"),
            SimError::NonPositiveWork { kernel } => {
                write!(f, "kernel {kernel} has non-positive work per block")
            }
            SimError::BlockNeverFits { kernel } => {
                write!(f, "kernel {kernel} has a block larger than one SM")
            }
            SimError::BadOrder => write!(f, "order is not a permutation of the kernel set"),
        }
    }
}

impl std::error::Error for SimError {}

/// One traced simulator event (only recorded by [`simulate_order_traced`]).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockEvent {
    pub t_ms: f64,
    pub kernel: usize,
    pub sm: u32,
    pub kind: BlockEventKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockEventKind {
    Placed,
    Finished,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Total time until the last block completes (the paper's
    /// "GPU execution time").
    pub makespan_ms: f64,
    /// Completion time of each kernel, indexed like the *input* kernel
    /// slice (not the order).
    pub kernel_finish_ms: Vec<f64>,
    /// Number of completion events processed.
    pub n_events: usize,
    /// Times the dispatcher hit head-of-line blocking with free SM slots
    /// elsewhere in the machine.
    pub dispatch_stalls: usize,
    /// Time-weighted mean of resident warps / total warp capacity.
    pub avg_warp_occupancy: f64,
    /// Optional event trace.
    pub trace: Vec<BlockEvent>,
}

#[derive(Debug, Clone)]
struct Block {
    kernel: u32,
    sm: u32,
    rem_work: f64,
}

/// Per-kernel constants hoisted out of the hot loop.
struct KernelConsts {
    res: ResourceVec,
    /// bytes of memory traffic per unit of compute work (1/R_i); 0 for
    /// pure-compute kernels.
    mem_per_work: f64,
    warps: f64,
}

/// Deterministic per-block execution-time factor in `1 ± jitter`
/// (SplitMix64 finalizer over the block index within its kernel).
///
/// Depends on the block index only — NOT on the kernel — so two identical
/// kernels present exactly the same block multiset and the paper's scope
/// property (identical kernels ⇒ order-invariant makespan) holds exactly.
#[inline]
fn block_jitter_factor(jitter: f64, block: u64) -> f64 {
    if jitter == 0.0 {
        return 1.0;
    }
    let mut z = block.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let u = (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64); // [0,1)
    1.0 + jitter * (2.0 * u - 1.0)
}

/// Simulate the given launch `order` (a permutation of kernel indices).
///
/// Call [`super::validate_workload`] first; this function `debug_assert`s
/// validity and produces meaningless results on invalid input in release
/// builds (it is the innermost loop of the permutation sweeps).
pub fn simulate_order(gpu: &GpuSpec, kernels: &[KernelProfile], order: &[usize]) -> SimResult {
    run(gpu, kernels, order, false)
}

/// As [`simulate_order`], but records a full placement/completion trace.
pub fn simulate_order_traced(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    order: &[usize],
) -> SimResult {
    run(gpu, kernels, order, true)
}

fn run(gpu: &GpuSpec, kernels: &[KernelProfile], order: &[usize], traced: bool) -> SimResult {
    debug_assert_eq!(order.len(), kernels.len());
    debug_assert!({
        let mut seen = vec![false; kernels.len()];
        order.iter().all(|&i| {
            let ok = i < kernels.len() && !seen[i];
            if ok {
                seen[i] = true;
            }
            ok
        })
    });

    let consts: Vec<KernelConsts> = kernels
        .iter()
        .map(|k| KernelConsts {
            res: k.block_resources(),
            mem_per_work: if k.ratio > 0.0 { 1.0 / k.ratio } else { 0.0 },
            warps: k.warps_per_block as f64,
        })
        .collect();

    // Block queue in launch order: (kernel index, per-block work with the
    // deterministic jitter factor applied). The factor depends only on
    // (kernel, block index), never on the order, so permutations see the
    // same physical workload.
    let total_blocks: usize = kernels.iter().map(|k| k.n_blocks as usize).sum();
    let mut queue: Vec<(u32, f64)> = Vec::with_capacity(total_blocks);
    for &ki in order {
        let k = &kernels[ki];
        for b in 0..k.n_blocks {
            let jitter = block_jitter_factor(gpu.block_jitter, b as u64);
            queue.push((ki as u32, k.work_per_block * jitter));
        }
    }
    let mut queue_head = 0usize;

    let n_sm = gpu.n_sm as usize;
    let sm_cap = gpu.sm_capacity();
    let mut sm_used = vec![ResourceVec::ZERO; n_sm];
    let mut resident: Vec<Block> = Vec::with_capacity(n_sm * gpu.blocks_per_sm as usize);

    let mut blocks_left: Vec<u32> = kernels.iter().map(|k| k.n_blocks).collect();
    let mut kernel_finish = vec![0.0f64; kernels.len()];

    let bandwidth = gpu.memory_bandwidth();
    let warp_capacity = (gpu.warps_per_sm * gpu.n_sm) as f64;
    let saturate = gpu.warps_to_saturate as f64;

    let mut t = 0.0f64;
    let mut n_events = 0usize;
    let mut dispatch_stalls = 0usize;
    let mut occupancy_integral = 0.0f64;
    let mut trace = Vec::new();

    // Scratch buffers reused across events (hot loop: zero allocations
    // per event after warm-up — see EXPERIMENTS.md §Perf).
    let mut rates: Vec<f64> = Vec::new();
    let mut demands: Vec<f64> = Vec::new();
    let mut sorted_scratch: Vec<f64> = Vec::new();

    loop {
        // ---- dispatch: place head blocks while they fit somewhere ----
        while queue_head < queue.len() {
            let (ki, block_work) = queue[queue_head];
            let ki = ki as usize;
            let need = &consts[ki].res;
            // Least-loaded-by-warps SM that fits; ties to lowest index.
            let mut best: Option<usize> = None;
            for s in 0..n_sm {
                if (sm_used[s] + *need).fits_within(&sm_cap) {
                    match best {
                        None => best = Some(s),
                        Some(b) if sm_used[s].warps < sm_used[b].warps => best = Some(s),
                        _ => {}
                    }
                }
            }
            let Some(s) = best else {
                if resident.len() < n_sm * gpu.blocks_per_sm as usize {
                    dispatch_stalls += 1;
                }
                break;
            };
            sm_used[s] += *need;
            resident.push(Block {
                kernel: ki as u32,
                sm: s as u32,
                rem_work: block_work,
            });
            if traced {
                trace.push(BlockEvent {
                    t_ms: t,
                    kernel: ki,
                    sm: s as u32,
                    kind: BlockEventKind::Placed,
                });
            }
            queue_head += 1;
        }

        if resident.is_empty() {
            debug_assert_eq!(queue_head, queue.len(), "dispatcher deadlocked");
            break;
        }

        // ---- rates: processor-sharing compute + max-min-fair memory ----
        rates.clear();
        rates.reserve(resident.len());
        // Per-SM warp totals.
        let mut sm_warps = [0.0f64; 64];
        debug_assert!(n_sm <= 64);
        for b in &resident {
            sm_warps[b.sm as usize] += consts[b.kernel as usize].warps;
        }
        let mut resident_warps = 0.0;
        for s in 0..n_sm {
            resident_warps += sm_warps[s];
        }
        for b in &resident {
            let kc = &consts[b.kernel as usize];
            let denom = sm_warps[b.sm as usize].max(saturate);
            rates.push(gpu.compute_rate_per_sm * kc.warps / denom);
        }

        // Max-min fair bandwidth: find the water level L with
        // sum(min(d_b, L)) = B, then p_b = min(c_b, grant_b * R_b).
        demands.clear();
        demands.reserve(resident.len());
        let mut total_demand = 0.0;
        for (i, b) in resident.iter().enumerate() {
            let d = rates[i] * consts[b.kernel as usize].mem_per_work;
            demands.push(d);
            total_demand += d;
        }
        if total_demand > bandwidth {
            // Water-filling over the sorted demands (reused scratch).
            sorted_scratch.clear();
            sorted_scratch.extend_from_slice(&demands);
            sorted_scratch.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            let mut rem = bandwidth;
            let mut level = f64::INFINITY;
            let mut m = sorted_scratch.len();
            for d in &sorted_scratch {
                let fair = rem / m as f64;
                if *d <= fair {
                    rem -= d;
                    m -= 1;
                } else {
                    level = fair;
                    break;
                }
            }
            for (i, b) in resident.iter().enumerate() {
                let kc = &consts[b.kernel as usize];
                if demands[i] > level && kc.mem_per_work > 0.0 {
                    // Memory-throttled: granted `level` bytes/ms.
                    rates[i] = rates[i].min(level / kc.mem_per_work);
                }
            }
        }

        // ---- advance to earliest completion ----
        let mut dt = f64::INFINITY;
        for (i, b) in resident.iter().enumerate() {
            let ti = b.rem_work / rates[i];
            if ti < dt {
                dt = ti;
            }
        }
        debug_assert!(dt.is_finite() && dt > 0.0);
        t += dt;
        occupancy_integral += resident_warps / warp_capacity * dt;
        n_events += 1;

        // Retire finished blocks (everything within float noise of done).
        let eps = dt * 1e-9;
        let mut i = 0;
        while i < resident.len() {
            let finished = {
                let b = &mut resident[i];
                b.rem_work -= rates[i] * dt;
                b.rem_work <= rates[i] * eps
            };
            if finished {
                let b = resident.swap_remove(i);
                let r = rates.swap_remove(i);
                let _ = r;
                sm_used[b.sm as usize] -= consts[b.kernel as usize].res;
                debug_assert!(sm_used[b.sm as usize].non_negative());
                let k = b.kernel as usize;
                blocks_left[k] -= 1;
                if blocks_left[k] == 0 {
                    kernel_finish[k] = t;
                }
                if traced {
                    trace.push(BlockEvent {
                        t_ms: t,
                        kernel: k,
                        sm: b.sm,
                        kind: BlockEventKind::Finished,
                    });
                }
            } else {
                i += 1;
            }
        }
    }

    SimResult {
        makespan_ms: t,
        kernel_finish_ms: kernel_finish,
        n_events,
        dispatch_stalls,
        avg_warp_occupancy: if t > 0.0 { occupancy_integral / t } else { 0.0 },
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::kernel;
    use super::*;
    use crate::gpu::GpuSpec;

    /// Deterministic test GPU with a low saturation point so the exact
    /// arithmetic below is easy to verify by hand.
    fn tgpu() -> GpuSpec {
        let mut g = GpuSpec::gtx580().deterministic();
        g.warps_to_saturate = 16;
        g
    }

    #[test]
    fn single_kernel_single_block_time() {
        let gpu = tgpu();
        // One block, 16 warps (saturating), pure compute (huge ratio).
        let ks = vec![kernel("k", 1, 16, 0, 1e9, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        // Saturated single block: rate = compute_rate_per_sm.
        assert!((r.makespan_ms - 1.0).abs() < 1e-9, "{}", r.makespan_ms);
        assert_eq!(r.n_events, 1);
    }

    #[test]
    fn undersaturated_block_runs_slower() {
        let gpu = tgpu();
        // 4 warps < warps_to_saturate=16 -> rate = C * 4/16.
        let ks = vec![kernel("k", 1, 4, 0, 1e9, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        assert!((r.makespan_ms - 4.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn jitter_spreads_block_times() {
        let mut gpu = tgpu();
        gpu.block_jitter = 0.10;
        // Two blocks of the same kernel on different SMs: their finish
        // times differ by the jitter factors but stay within ±10%.
        let ks = vec![kernel("k", 2, 16, 0, 1e9, 1000.0)];
        let r = simulate_order_traced(&gpu, &ks, &[0]);
        let finishes: Vec<f64> = r
            .trace
            .iter()
            .filter(|e| e.kind == BlockEventKind::Finished)
            .map(|e| e.t_ms)
            .collect();
        assert_eq!(finishes.len(), 2);
        for t in &finishes {
            assert!((0.9..=1.1).contains(t), "{t}");
        }
        assert!((finishes[0] - finishes[1]).abs() > 1e-6);
    }

    #[test]
    fn two_identical_blocks_one_sm_share_compute() {
        // Force both blocks onto one SM: a 1-SM GPU variant.
        let mut gpu1 = tgpu();
        gpu1.n_sm = 1;
        let ks = vec![kernel("k", 2, 16, 0, 1e9, 1000.0)];
        let r = simulate_order(&gpu1, &ks, &[0]);
        // 32 resident warps, each block gets C/2 -> both finish at 2 ms.
        assert!((r.makespan_ms - 2.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn blocks_spread_across_sms() {
        let gpu = tgpu();
        // 16 blocks on 16 SMs: each alone, saturating -> 1 ms total.
        let ks = vec![kernel("k", 16, 16, 0, 1e9, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        assert!((r.makespan_ms - 1.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn memory_bound_kernel_is_bandwidth_limited() {
        let gpu = tgpu();
        // Fill the GPU with saturating, very memory-bound blocks (R = 1
        // << R_B = 4.11). 16 blocks x 16 warps, work 1000 each.
        let ks = vec![kernel("k", 16, 16, 0, 1.0, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        // Total mem = 16 * 1000 / 1.0 = 16000 bytes; B = 16000/4.11 -> t =
        // 16000/(16*1000/4.11) = 4.11 ms (bandwidth-limited).
        assert!((r.makespan_ms - 4.11).abs() < 1e-6, "{}", r.makespan_ms);
    }

    #[test]
    fn balanced_kernel_hits_lower_bound() {
        let gpu = tgpu();
        let ks = vec![kernel("k", 16, 16, 0, gpu.balanced_ratio, 1000.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        let lb = gpu.makespan_lower_bound(ks[0].total_work(), ks[0].total_mem());
        assert!((r.makespan_ms - lb).abs() < 1e-6);
    }

    #[test]
    fn mixing_compute_and_memory_bound_beats_segregation() {
        // The paper's core claim: co-residency of opposing kernel types
        // outperforms same-type clustering. Build 2 memory-bound + 2
        // compute-bound kernels, each sized at half the SM warp budget so
        // exactly two kernels co-reside per round.
        let gpu = tgpu();
        let mem = || kernel("mem", 16, 24, 0, 1.0, 3000.0);
        let cmp = || kernel("cmp", 16, 24, 0, 1e9, 3000.0);
        let ks = vec![mem(), mem(), cmp(), cmp()];
        let segregated = simulate_order(&gpu, &ks, &[0, 1, 2, 3]).makespan_ms;
        let interleaved = simulate_order(&gpu, &ks, &[0, 2, 1, 3]).makespan_ms;
        assert!(
            interleaved < segregated * 0.999,
            "interleaved {interleaved} !< segregated {segregated}"
        );
    }

    #[test]
    fn head_of_line_blocking_penalizes_bad_order() {
        // A shared-memory hog (48K/block) blocks everything behind it on
        // the same SM; launching hogs first then small kernels lets the
        // small ones pack around them, while alternating strands capacity.
        let gpu = tgpu();
        let hog = || kernel("hog", 16, 4, 48 * 1024, 1e9, 4000.0);
        let tiny = || kernel("tiny", 16, 4, 0, 1e9, 1000.0);
        let ks = vec![hog(), hog(), tiny(), tiny()];
        let good = simulate_order(&gpu, &ks, &[0, 2, 1, 3]).makespan_ms;
        let bad = simulate_order(&gpu, &ks, &[0, 1, 2, 3]).makespan_ms;
        assert!(good <= bad, "good {good} > bad {bad}");
    }

    #[test]
    fn identical_kernels_order_invariant() {
        // Paper, Scope & Applicability: identical kernels differing only
        // in block count -> order does not matter. Holds with jitter ON
        // because the jitter factor depends only on the block index.
        let gpu = GpuSpec::gtx580();
        assert!(gpu.block_jitter > 0.0);
        let ks = vec![
            kernel("a", 8, 8, 4096, 3.0, 500.0),
            kernel("b", 24, 8, 4096, 3.0, 500.0),
            kernel("c", 16, 8, 4096, 3.0, 500.0),
        ];
        let t0 = simulate_order(&gpu, &ks, &[0, 1, 2]).makespan_ms;
        for order in [[0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]] {
            let t = simulate_order(&gpu, &ks, &order).makespan_ms;
            assert!(
                (t - t0).abs() < 1e-6 * t0,
                "order {order:?}: {t} vs {t0}"
            );
        }
    }

    #[test]
    fn all_blocks_complete_and_finish_times_recorded() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 20, 8, 8192, 2.0, 700.0),
            kernel("b", 40, 12, 0, 9.0, 300.0),
        ];
        let r = simulate_order(&gpu, &ks, &[1, 0]);
        assert_eq!(r.n_events as u32 >= 1, true);
        for (i, &f) in r.kernel_finish_ms.iter().enumerate() {
            assert!(f > 0.0, "kernel {i} never finished");
            assert!(f <= r.makespan_ms + 1e-12);
        }
        assert!((r.kernel_finish_ms.iter().cloned().fold(0.0, f64::max)
            - r.makespan_ms)
            .abs()
            < 1e-12);
    }

    #[test]
    fn trace_is_balanced_and_ordered() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 10, 8, 0, 3.0, 500.0),
            kernel("b", 10, 8, 0, 9.0, 500.0),
        ];
        let r = simulate_order_traced(&gpu, &ks, &[0, 1]);
        let placed = r.trace.iter().filter(|e| e.kind == BlockEventKind::Placed).count();
        let finished = r.trace.iter().filter(|e| e.kind == BlockEventKind::Finished).count();
        assert_eq!(placed, 20);
        assert_eq!(finished, 20);
        // Timestamps non-decreasing.
        for w in r.trace.windows(2) {
            assert!(w[0].t_ms <= w[1].t_ms + 1e-12);
        }
    }

    #[test]
    fn makespan_never_beats_lower_bound() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 16, 4, 8192, 3.11, 800.0),
            kernel("b", 32, 8, 0, 11.1, 400.0),
            kernel("c", 48, 6, 16384, 2.0, 300.0),
        ];
        let total_work: f64 = ks.iter().map(|k| k.total_work()).sum();
        let total_mem: f64 = ks.iter().map(|k| k.total_mem()).sum();
        let lb = gpu.makespan_lower_bound(total_work, total_mem);
        for order in [[0, 1, 2], [2, 1, 0], [1, 0, 2]] {
            let r = simulate_order(&gpu, &ks, &order);
            assert!(r.makespan_ms >= lb * (1.0 - 1e-9));
        }
    }

    #[test]
    fn occupancy_fraction_sane() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel("a", 64, 8, 0, 4.0, 500.0)];
        let r = simulate_order(&gpu, &ks, &[0]);
        assert!(r.avg_warp_occupancy > 0.0 && r.avg_warp_occupancy <= 1.0);
    }
}
