//! The paper's analytic *execution round* model.
//!
//! "Thread blocks from a set of kernels are split into multiple execution
//! rounds, which are sequentially executed one after the other." A kernel
//! joins the current round if its per-SM footprint (grid spread round-robin
//! over the SMs) still fits together with the kernels already in the round;
//! otherwise a new round opens.
//!
//! This model is used two ways:
//! * as Algorithm 1's *fit test* ("all kernels whose resource can fit
//!   within `Rd_r`", line 8);
//! * for round-composition reporting (which kernels co-execute, each
//!   round's combined `R_comb`).

use crate::gpu::{GpuSpec, KernelProfile, ResourceVec};

/// One execution round: the kernels the round-robin dispatcher would have
/// co-resident, in launch order.
#[derive(Debug, Clone, PartialEq)]
pub struct Round {
    /// Kernel indices (into the workload slice), in launch order.
    pub kernels: Vec<usize>,
    /// Combined per-SM footprint of the round.
    pub footprint: ResourceVec,
    /// Combined instructions/bytes ratio `R_comb` of the round
    /// (work-weighted, the paper's ProfileCombine).
    pub combined_ratio: f64,
}

/// Pack `order` into execution rounds against `gpu`'s per-SM capacity.
pub fn pack_rounds(gpu: &GpuSpec, kernels: &[KernelProfile], order: &[usize]) -> Vec<Round> {
    let cap = gpu.sm_capacity();
    let mut rounds: Vec<Round> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    let mut used = ResourceVec::ZERO;

    for &ki in order {
        let f = kernels[ki].per_sm_footprint(gpu);
        if !cur.is_empty() && !(used + f).fits_within(&cap) {
            rounds.push(finish_round(kernels, std::mem::take(&mut cur), used));
            used = ResourceVec::ZERO;
        }
        used += f;
        cur.push(ki);
    }
    if !cur.is_empty() {
        rounds.push(finish_round(kernels, cur, used));
    }
    rounds
}

/// Would kernel `cand` fit into a round already holding `used` footprint?
pub fn fits_in_round(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    used: &ResourceVec,
    cand: usize,
) -> bool {
    let f = kernels[cand].per_sm_footprint(gpu);
    (*used + f).fits_within(&gpu.sm_capacity())
}

/// Work-weighted combined instructions/bytes ratio of a kernel set — the
/// paper's `R_comb`: total instructions over total memory traffic.
pub fn combined_ratio(kernels: &[KernelProfile], ids: &[usize]) -> f64 {
    let work: f64 = ids.iter().map(|&i| kernels[i].total_work()).sum();
    let mem: f64 = ids.iter().map(|&i| kernels[i].total_mem()).sum();
    if mem <= 0.0 {
        f64::INFINITY
    } else {
        work / mem
    }
}

fn finish_round(kernels: &[KernelProfile], ids: Vec<usize>, used: ResourceVec) -> Round {
    let ratio = combined_ratio(kernels, &ids);
    Round {
        kernels: ids,
        footprint: used,
        combined_ratio: ratio,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::AppKind;

    fn kernel(n_blocks: u32, warps: u32, shmem: u32, ratio: f64) -> KernelProfile {
        KernelProfile {
            name: format!("k{n_blocks}x{warps}"),
            app: AppKind::Synthetic,
            n_blocks,
            regs_per_block: 512,
            shmem_per_block: shmem,
            warps_per_block: warps,
            ratio,
            work_per_block: 100.0,
            artifact: String::new(),
        }
    }

    #[test]
    fn all_fit_in_one_round() {
        let gpu = GpuSpec::gtx580();
        // 3 kernels x 16 blocks x 8 warps = 24 warps/SM < 48.
        let ks = vec![kernel(16, 8, 0, 3.0); 3];
        let r = pack_rounds(&gpu, &ks, &[0, 1, 2]);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].kernels, vec![0, 1, 2]);
        assert_eq!(r[0].footprint.warps, 24.0);
    }

    #[test]
    fn shmem_splits_rounds() {
        let gpu = GpuSpec::gtx580();
        // Each kernel needs 24K shmem per SM: two per round (48K cap).
        let ks = vec![kernel(16, 4, 24 * 1024, 3.0); 4];
        let r = pack_rounds(&gpu, &ks, &[0, 1, 2, 3]);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].kernels, vec![0, 1]);
        assert_eq!(r[1].kernels, vec![2, 3]);
    }

    #[test]
    fn order_changes_round_count() {
        // The paper's motivating effect: 48K + 8K + 40K + 16K shmem
        // kernels. Order (48,8,40,16): [48], [8+40], [16] = 3 rounds
        // vs (48,16,40,8) -> [48], [16,..no 40 doesn't fit..] hmm;
        // use (8,40,48,16): [8+40],[48],[16] = 3 vs (48,16,8,40)... pick
        // a pair of orders with different round counts:
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel(16, 4, 48 * 1024, 3.0), // 0: 48K
            kernel(16, 4, 8 * 1024, 3.0),  // 1: 8K
            kernel(16, 4, 40 * 1024, 3.0), // 2: 40K
            kernel(16, 4, 16 * 1024, 3.0), // 3: 16K
        ];
        // 48 | 8+40 | 16  -> 3 rounds
        let a = pack_rounds(&gpu, &ks, &[0, 1, 2, 3]);
        // 8+16 | 40 | 48 -> wait 8+16=24, +40 doesn't fit -> rounds
        // [8,16],[40],[48] = 3. Try: 8+40 | 48 | 16: same 3.
        // 16+8 | 48 | 40: 3. Hmm — find a 2-round order: 48 | 40+8 | 16?
        // 40+8 = 48K full, 16 opens third. Best is [8+40][16+..48 no]..
        // Actually 2 rounds impossible (sum=112K > 2*48K); 3 is optimal;
        // worst is 4: order (40, 16, 48, 8): 40 | 16 (48 no fit after 16?
        // 16+48=64K no) -> 40 | 16 | 48+8? 48+8=56K no -> 40 | 16 | 48 | 8.
        let b = pack_rounds(&gpu, &ks, &[2, 3, 0, 1]);
        assert_eq!(a.len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn block_slots_bind() {
        let gpu = GpuSpec::gtx580();
        // 5 kernels x 32 blocks = 2 blocks/SM each; block cap 8 -> 4 per
        // round.
        let ks = vec![kernel(32, 2, 0, 3.0); 5];
        let r = pack_rounds(&gpu, &ks, &[0, 1, 2, 3, 4]);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].kernels.len(), 4);
        assert_eq!(r[1].kernels.len(), 1);
    }

    #[test]
    fn combined_ratio_work_weighted() {
        let ks = vec![kernel(16, 4, 0, 2.0), kernel(16, 4, 0, 8.0)];
        // Equal work W each; mem = W/2 + W/8 = 0.625W -> R = 2W/0.625W = 3.2.
        let r = combined_ratio(&ks, &[0, 1]);
        assert!((r - 3.2).abs() < 1e-12, "{r}");
    }

    #[test]
    fn combined_ratio_pure_compute_is_infinite() {
        let mut k = kernel(16, 4, 0, 2.0);
        k.ratio = 0.0; // treated as no memory traffic
        assert_eq!(combined_ratio(&[k], &[0]), f64::INFINITY);
    }

    #[test]
    fn fits_in_round_matches_pack() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel(16, 24, 0, 3.0),
            kernel(16, 24, 0, 5.0),
            kernel(16, 8, 0, 7.0),
        ];
        let used = ks[0].per_sm_footprint(&gpu) + ks[1].per_sm_footprint(&gpu);
        // 24+24 = 48 warps used; kernel 2 (8 warps) cannot join.
        assert!(!fits_in_round(&gpu, &ks, &used, 2));
        let used01 = ks[0].per_sm_footprint(&gpu);
        assert!(fits_in_round(&gpu, &ks, &used01, 1));
    }

    #[test]
    fn rounds_partition_the_kernel_set() {
        let gpu = GpuSpec::gtx580();
        let ks: Vec<_> = (0..7).map(|i| kernel(16, 4 + 4 * i, 0, 3.0)).collect();
        let order: Vec<usize> = (0..7).collect();
        let rounds = pack_rounds(&gpu, &ks, &order);
        let mut seen: Vec<usize> = rounds.iter().flat_map(|r| r.kernels.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
    }
}
