//! Concurrent-kernel GPU execution simulator — the hardware substrate that
//! replaces the paper's GTX580 testbed (see DESIGN.md §2, §5).
//!
//! Two models are provided:
//!
//! * [`simulate_order`] — the **event-driven fluid simulator**: thread
//!   blocks are dispatched strictly in launch order (head-of-line, the
//!   Fermi behaviour the paper and Pai et al. describe), occupy per-SM
//!   resources (registers / shared memory / warps / block slots), and
//!   drain their compute and memory work under processor-sharing rates
//!   with max-min-fair global memory bandwidth. This is what every
//!   experiment times.
//! * [`rounds::pack_rounds`] — the paper's **analytic round model**:
//!   kernels greedily pack into *execution rounds* by per-SM footprint.
//!   Algorithm 1 uses it as its fit test; reports use it to show round
//!   composition.
//!
//! Why ordering matters in this simulator, exactly as in the paper:
//! the in-order dispatcher stalls on the first block that does not fit
//! (head-of-line blocking), so a launch order that packs resource-
//! imbalanced kernels together strands SM capacity; and the memory system
//! is a shared bandwidth pool, so co-scheduling only memory-bound kernels
//! (combined ratio far below `R_B`) collapses everyone's progress rate.

mod engine;
pub mod rounds;

pub use engine::{
    simulate_order, simulate_order_traced, BlockEvent, BlockEventKind, SimError, SimResult,
    SimState,
};

use crate::gpu::{GpuSpec, KernelProfile};

/// Simulate the identity (FIFO) order.
pub fn simulate_fifo(gpu: &GpuSpec, kernels: &[KernelProfile]) -> SimResult {
    let order: Vec<usize> = (0..kernels.len()).collect();
    simulate_order(gpu, kernels, &order)
}

/// Validate that a workload is simulable: every kernel has blocks and every
/// block individually fits on an empty SM (otherwise the in-order
/// dispatcher would deadlock — and no launch order could help).
pub fn validate_workload(gpu: &GpuSpec, kernels: &[KernelProfile]) -> Result<(), SimError> {
    for (i, k) in kernels.iter().enumerate() {
        if k.n_blocks == 0 {
            return Err(SimError::EmptyKernel { kernel: i });
        }
        if k.work_per_block <= 0.0 {
            return Err(SimError::NonPositiveWork { kernel: i });
        }
        if !k.block_fits(gpu) {
            return Err(SimError::BlockNeverFits { kernel: i });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::AppKind;

    pub(crate) fn kernel(name: &str, n_blocks: u32, warps: u32, shmem: u32, ratio: f64, work: f64) -> KernelProfile {
        KernelProfile {
            name: name.into(),
            app: AppKind::Synthetic,
            n_blocks,
            regs_per_block: 1024,
            shmem_per_block: shmem,
            warps_per_block: warps,
            ratio,
            work_per_block: work,
            artifact: String::new(),
        }
    }

    #[test]
    fn validate_rejects_empty_kernel() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel("k", 0, 4, 0, 4.0, 100.0)];
        assert!(matches!(
            validate_workload(&gpu, &ks),
            Err(SimError::EmptyKernel { kernel: 0 })
        ));
    }

    #[test]
    fn validate_rejects_oversized_block() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![kernel("k", 1, 64, 0, 4.0, 100.0)]; // 64 warps > 48
        assert!(matches!(
            validate_workload(&gpu, &ks),
            Err(SimError::BlockNeverFits { kernel: 0 })
        ));
    }

    #[test]
    fn validate_accepts_paper_scale() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("ep", 16, 4, 8192, 3.11, 100.0),
            kernel("bs", 32, 8, 0, 11.1, 400.0),
        ];
        assert!(validate_workload(&gpu, &ks).is_ok());
    }

    #[test]
    fn fifo_equals_identity_order() {
        let gpu = GpuSpec::gtx580();
        let ks = vec![
            kernel("a", 16, 4, 8192, 3.11, 100.0),
            kernel("b", 32, 8, 0, 11.1, 400.0),
        ];
        let a = simulate_fifo(&gpu, &ks);
        let b = simulate_order(&gpu, &ks, &[0, 1]);
        assert_eq!(a.makespan_ms, b.makespan_ms);
    }
}
