//! [`WindowPolicy`] — *when* to close a reorder window.
//!
//! Offline, a reorder window is just "the batch": everything is known up
//! front and the only question is the order. Online, the window is a
//! **time** decision — close early and you give up reordering freedom
//! (small batches ≈ FIFO), close late and every queued kernel pays the
//! wait in its sojourn time. The policies here decide that trade-off
//! from a [`WindowState`] snapshot, and are shared by two consumers:
//!
//! * the virtual-clock online engine ([`crate::online::simulate_online`]),
//!   where `now_ms` is simulated time and decisions are re-evaluated at
//!   every event;
//! * the thread coordinator
//!   ([`crate::coordinator::CoordinatorBuilder::window_policy`]), where
//!   `now_ms` derives from the injectable batch clock and `Wait`
//!   deadlines bound the dispatcher's `recv_timeout`.
//!
//! | spelling | behavior |
//! |---|---|
//! | `fixed:<k>` | close only when `k` kernels are pending (drain closes remainders) |
//! | `linger:<k>:<ms>` | close at `k` kernels or when the oldest pending kernel has waited `ms` |
//! | `adaptive:<k>:<ms>` | linger-deadline, but occupancy-aware: batch freely while the device is busy, dispatch after a short grace when it is idle |
//!
//! Policies must be **deterministic pure functions of the state they are
//! shown** — the online engine's bit-identical-replay guarantee
//! (`tests/online_determinism.rs`) rests on it.

use std::fmt;

/// Snapshot of the open reorder window a [`WindowPolicy`] decides over.
#[derive(Debug, Clone, Copy)]
pub struct WindowState {
    /// Current time (virtual in the online engine, clock-derived in the
    /// coordinator).
    pub now_ms: f64,
    /// Kernels currently pending in the open window.
    pub n_pending: usize,
    /// Arrival time of the oldest pending kernel (meaningless when
    /// `n_pending == 0`).
    pub oldest_arrival_ms: f64,
    /// Earliest time the executing device frees (`<= now_ms` means
    /// idle). The thread coordinator cannot predict when a worker frees
    /// and passes `now_ms`; it reports occupancy through
    /// `queued_batches` instead.
    pub device_free_at_ms: f64,
    /// Batches already closed but not yet finished on the device (the
    /// thread coordinator reports the least-loaded worker's depth here).
    pub queued_batches: usize,
}

impl WindowState {
    /// Whether the device could accept a batch right now (idle and
    /// nothing queued ahead).
    pub fn device_idle(&self) -> bool {
        self.device_free_at_ms <= self.now_ms && self.queued_batches == 0
    }
}

/// A window policy's verdict for the current instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WindowDecision {
    /// Close the window now: reorder and dispatch the pending kernels.
    Close,
    /// Keep the window open. `recheck_at_ms` is the next time the
    /// decision could flip with no new arrivals (`None` = only a new
    /// arrival or end-of-stream drain can close it).
    Wait { recheck_at_ms: Option<f64> },
}

/// Decides when the open reorder window closes.
///
/// Contract (the event loops rely on it):
/// * never `Close` on an empty window (`n_pending == 0`);
/// * any `recheck_at_ms` must be **strictly greater** than
///   `state.now_ms` — a policy whose deadline has already passed must
///   return `Close` instead, or the caller would spin without progress.
pub trait WindowPolicy: Send {
    /// Registry spelling of this policy instance (e.g. `"linger:8:50"`).
    fn name(&self) -> String;

    /// Decide whether to close the window at `state.now_ms`.
    fn decide(&mut self, state: &WindowState) -> WindowDecision;
}

// ---------------------------------------------------------------------------
// Implementations
// ---------------------------------------------------------------------------

/// `fixed:<k>` — close only on occupancy. The simplest policy and the
/// one with no latency bound: a trickle of arrivals below `k` waits for
/// the end-of-stream drain.
#[derive(Debug, Clone, Copy)]
pub struct FixedWindow {
    cap: usize,
}

impl FixedWindow {
    pub fn new(cap: usize) -> Self {
        FixedWindow { cap: cap.max(1) }
    }
}

impl WindowPolicy for FixedWindow {
    fn name(&self) -> String {
        format!("fixed:{}", self.cap)
    }

    fn decide(&mut self, s: &WindowState) -> WindowDecision {
        if s.n_pending >= self.cap {
            WindowDecision::Close
        } else {
            WindowDecision::Wait { recheck_at_ms: None }
        }
    }
}

/// `linger:<k>:<ms>` — the serving-system classic: close at `k` kernels
/// or once the oldest pending kernel has waited `ms`. The linger bound
/// is the window's contribution to the per-kernel latency SLO: no
/// kernel waits more than `ms` for its window to close.
#[derive(Debug, Clone, Copy)]
pub struct LingerWindow {
    cap: usize,
    linger_ms: f64,
}

impl LingerWindow {
    pub fn new(cap: usize, linger_ms: f64) -> Self {
        LingerWindow {
            cap: cap.max(1),
            linger_ms: linger_ms.max(0.0),
        }
    }
}

impl WindowPolicy for LingerWindow {
    fn name(&self) -> String {
        format!("linger:{}:{}", self.cap, self.linger_ms)
    }

    fn decide(&mut self, s: &WindowState) -> WindowDecision {
        if s.n_pending == 0 {
            return WindowDecision::Wait { recheck_at_ms: None };
        }
        let deadline = s.oldest_arrival_ms + self.linger_ms;
        if s.n_pending >= self.cap || s.now_ms >= deadline {
            WindowDecision::Close
        } else {
            WindowDecision::Wait {
                recheck_at_ms: Some(deadline),
            }
        }
    }
}

/// Fraction of the linger budget an [`AdaptiveWindow`] waits before
/// dispatching to an **idle** device: long enough that a back-to-back
/// burst coalesces into one window, short enough that an isolated
/// kernel's sojourn stays near its bare service time.
const IDLE_GRACE_FRACTION: f64 = 0.125;

/// `adaptive:<k>:<ms>` — occupancy-aware linger. While the device is
/// busy (or batches are queued ahead), waiting costs nothing — the
/// kernel would queue anyway — so the window keeps filling toward `k`
/// until the device frees or the linger deadline lands. When the device
/// is idle, every queued millisecond is pure added latency, so the
/// window closes after a short grace (`IDLE_GRACE_FRACTION` of the
/// linger budget). Under light load this behaves like near-immediate
/// dispatch; under heavy load it converges to full `k`-windows, which
/// is where reordering pays most.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveWindow {
    cap: usize,
    linger_ms: f64,
}

impl AdaptiveWindow {
    pub fn new(cap: usize, linger_ms: f64) -> Self {
        AdaptiveWindow {
            cap: cap.max(1),
            linger_ms: linger_ms.max(0.0),
        }
    }
}

impl WindowPolicy for AdaptiveWindow {
    fn name(&self) -> String {
        format!("adaptive:{}:{}", self.cap, self.linger_ms)
    }

    fn decide(&mut self, s: &WindowState) -> WindowDecision {
        if s.n_pending == 0 {
            return WindowDecision::Wait { recheck_at_ms: None };
        }
        let deadline = s.oldest_arrival_ms + self.linger_ms;
        if s.n_pending >= self.cap || s.now_ms >= deadline {
            return WindowDecision::Close;
        }
        if !s.device_idle() {
            // Batching is free while the device cannot take the batch:
            // recheck when it frees (if that is ever known to the
            // caller's clock) or at the hard linger deadline.
            let recheck = if s.device_free_at_ms > s.now_ms {
                s.device_free_at_ms.min(deadline)
            } else {
                deadline
            };
            return WindowDecision::Wait {
                recheck_at_ms: Some(recheck),
            };
        }
        let grace = s.oldest_arrival_ms + self.linger_ms * IDLE_GRACE_FRACTION;
        if s.now_ms >= grace {
            WindowDecision::Close
        } else {
            WindowDecision::Wait {
                recheck_at_ms: Some(grace.min(deadline)),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Error for unknown window-policy spellings; `Display` lists the valid
/// forms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WindowParseError {
    pub input: String,
}

impl fmt::Display for WindowParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown window policy `{}` — valid policies: fixed:<k>, linger:<k>:<ms>, \
             adaptive:<k>:<ms>",
            self.input
        )
    }
}

impl std::error::Error for WindowParseError {}

/// Parse a window-policy spelling (`"fixed:8"`, `"linger:8:50"`,
/// `"adaptive:16:100"`) into a trait object.
///
/// ```
/// let p = kreorder::online::parse_window_policy("linger:8:50").unwrap();
/// assert_eq!(p.name(), "linger:8:50");
/// assert!(kreorder::online::parse_window_policy("nope").is_err());
/// ```
pub fn parse_window_policy(s: &str) -> Result<Box<dyn WindowPolicy>, WindowParseError> {
    let lower = s.to_ascii_lowercase();
    let err = || WindowParseError { input: s.into() };
    let mut parts = lower.split(':');
    let head = parts.next().unwrap_or("");
    let cap = |p: Option<&str>| -> Result<usize, WindowParseError> {
        p.ok_or_else(err)?.parse::<usize>().map_err(|_| err())
    };
    let ms = |p: Option<&str>| -> Result<f64, WindowParseError> {
        let v: f64 = p.ok_or_else(err)?.parse().map_err(|_| err())?;
        if v.is_finite() && v >= 0.0 {
            Ok(v)
        } else {
            Err(err())
        }
    };
    let policy: Box<dyn WindowPolicy> = match head {
        "fixed" => Box::new(FixedWindow::new(cap(parts.next())?)),
        "linger" => Box::new(LingerWindow::new(cap(parts.next())?, ms(parts.next())?)),
        "adaptive" => Box::new(AdaptiveWindow::new(cap(parts.next())?, ms(parts.next())?)),
        _ => return Err(err()),
    };
    if parts.next().is_some() {
        return Err(err());
    }
    Ok(policy)
}

/// Human-readable table of the window-policy spellings (one per line).
pub fn window_policy_help_table() -> String {
    let rows = [
        ("fixed:<k>", "close only when k kernels are pending (no latency bound)"),
        (
            "linger:<k>:<ms>",
            "close at k kernels or when the oldest has waited ms (latency SLO bound)",
        ),
        (
            "adaptive:<k>:<ms>",
            "linger, but occupancy-aware: fill while the device is busy, dispatch fast when idle",
        ),
    ];
    let mut out = String::new();
    for (name, desc) in rows {
        out.push_str(&format!("  {name:<20} {desc}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(now: f64, n: usize, oldest: f64, free_at: f64, queued: usize) -> WindowState {
        WindowState {
            now_ms: now,
            n_pending: n,
            oldest_arrival_ms: oldest,
            device_free_at_ms: free_at,
            queued_batches: queued,
        }
    }

    fn wait_until(d: WindowDecision) -> Option<f64> {
        match d {
            WindowDecision::Wait { recheck_at_ms } => recheck_at_ms,
            WindowDecision::Close => panic!("expected Wait, got Close"),
        }
    }

    #[test]
    fn fixed_closes_only_on_occupancy() {
        let mut p = FixedWindow::new(4);
        assert_eq!(wait_until(p.decide(&state(0.0, 0, 0.0, 0.0, 0))), None);
        assert_eq!(wait_until(p.decide(&state(1e9, 3, 0.0, 0.0, 0))), None);
        assert_eq!(p.decide(&state(0.0, 4, 0.0, 0.0, 0)), WindowDecision::Close);
        assert_eq!(p.decide(&state(0.0, 9, 0.0, 0.0, 0)), WindowDecision::Close);
    }

    #[test]
    fn linger_closes_on_cap_or_deadline() {
        let mut p = LingerWindow::new(8, 50.0);
        // Below cap, before deadline: wait exactly until the deadline.
        assert_eq!(wait_until(p.decide(&state(10.0, 2, 5.0, 0.0, 0))), Some(55.0));
        // Deadline reached.
        assert_eq!(p.decide(&state(55.0, 2, 5.0, 0.0, 0)), WindowDecision::Close);
        assert_eq!(p.decide(&state(80.0, 2, 5.0, 0.0, 0)), WindowDecision::Close);
        // Cap reached early.
        assert_eq!(p.decide(&state(6.0, 8, 5.0, 0.0, 0)), WindowDecision::Close);
        // Empty window never closes.
        assert_eq!(wait_until(p.decide(&state(1e9, 0, 0.0, 0.0, 0))), None);
    }

    #[test]
    fn linger_recheck_is_strictly_future() {
        // Contract: Wait deadlines are strictly after now.
        let mut p = LingerWindow::new(8, 50.0);
        for now in [0.0, 10.0, 54.9] {
            if let WindowDecision::Wait { recheck_at_ms: Some(t) } =
                p.decide(&state(now, 1, 5.0, 0.0, 0))
            {
                assert!(t > now, "recheck {t} !> now {now}");
            }
        }
    }

    #[test]
    fn adaptive_fills_while_busy_dispatches_fast_when_idle() {
        let mut p = AdaptiveWindow::new(8, 80.0);
        // Device busy until 100: keep filling, recheck when it frees.
        assert_eq!(
            wait_until(p.decide(&state(10.0, 3, 0.0, 100.0, 0))),
            Some(80.0f64.min(100.0))
        );
        // Device idle: close after the short grace (80 * 0.125 = 10).
        assert_eq!(
            wait_until(p.decide(&state(5.0, 3, 0.0, 0.0, 0))),
            Some(10.0)
        );
        assert_eq!(p.decide(&state(10.0, 3, 0.0, 0.0, 0)), WindowDecision::Close);
        // Queued batches count as busy even if the device reads idle.
        let d = p.decide(&state(5.0, 3, 0.0, 0.0, 2));
        assert_eq!(wait_until(d), Some(80.0));
        // Hard deadline closes regardless of occupancy.
        assert_eq!(p.decide(&state(80.0, 3, 0.0, 1e9, 0)), WindowDecision::Close);
        // Cap closes regardless of everything.
        assert_eq!(p.decide(&state(0.0, 8, 0.0, 1e9, 5)), WindowDecision::Close);
    }

    #[test]
    fn adaptive_busy_recheck_is_bounded_by_deadline() {
        let mut p = AdaptiveWindow::new(8, 20.0);
        // Device frees long after the linger deadline: recheck at the
        // deadline, not the device.
        assert_eq!(wait_until(p.decide(&state(0.0, 1, 0.0, 1e6, 0))), Some(20.0));
    }

    #[test]
    fn spellings_parse_and_round_trip() {
        for s in ["fixed:8", "linger:8:50", "adaptive:16:100", "LINGER:4:2.5"] {
            let p = parse_window_policy(s).unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(p.name(), s.to_ascii_lowercase());
            // Canonical names re-parse.
            assert!(parse_window_policy(&p.name()).is_ok());
        }
    }

    #[test]
    fn bad_spellings_error_and_list_names() {
        for s in [
            "nope",
            "fixed",
            "fixed:x",
            "linger:8",
            "linger:8:-1",
            "linger:8:nan",
            "adaptive:8:5:9",
            "fixed:8:2",
        ] {
            let err = parse_window_policy(s).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(s), "{msg}");
            for name in ["fixed:<k>", "linger:<k>:<ms>", "adaptive:<k>:<ms>"] {
                assert!(msg.contains(name), "missing {name} in: {msg}");
            }
        }
    }

    #[test]
    fn caps_clamp_to_one() {
        let mut p = FixedWindow::new(0);
        assert_eq!(p.decide(&state(0.0, 1, 0.0, 0.0, 0)), WindowDecision::Close);
        assert_eq!(p.name(), "fixed:1");
    }

    #[test]
    fn help_table_covers_registry() {
        let t = window_policy_help_table();
        for name in ["fixed:<k>", "linger:<k>:<ms>", "adaptive:<k>:<ms>"] {
            assert!(t.contains(name));
        }
    }
}
