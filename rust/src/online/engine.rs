//! The virtual-clock event loop: arrivals → reorder windows → device.
//!
//! [`simulate_online`] is a single-threaded discrete-event simulation.
//! Nothing ever sleeps on the wall clock — time is a plain `f64` of
//! virtual milliseconds advanced from event to event — so a run is a
//! pure function of its configuration: equal (arrival seed, strategy
//! seed, window policy, backend) produce **bit-identical** per-kernel
//! timestamps on every machine (`tests/online_determinism.rs` pins it).
//!
//! Four event kinds drive the loop, processed in this fixed priority at
//! equal times (ties are resolved deterministically, never by insertion
//! race):
//!
//! 1. **completion** — a kernel's model finish time passed (closed-loop
//!    sources schedule their next submission from it);
//! 2. **batch start** — the device is free and a closed window's
//!    decision overhead has elapsed;
//! 3. **arrival** — the source's next kernel joins the open window;
//! 4. **recheck** — a [`WindowPolicy`] `Wait` deadline landed.
//!
//! The window policy is consulted after every event; `Close` runs the
//! [`OnlineReorderer`] (bounded by its per-decision budget), queues the
//! batch behind the device, and the batch's per-kernel finish times come
//! from one [`crate::exec::ExecutionBackend::execute`] call — the same
//! timing model the offline layers use, now coupled to a clock.

use super::arrivals::ArrivalSource;
use super::report::{BatchRecord, KernelRecord, OnlineReport, ShedCause, ShedRecord};
use super::window::{WindowDecision, WindowPolicy, WindowState};
use super::OnlineReorderer;
use crate::admission::{AdmissionPolicy, AdmissionState, NoAdmission};
use crate::exec::ExecutionBackend;
use crate::gpu::{GpuSpec, KernelProfile};
use crate::obs::{NoTrace, TraceEvent, TraceSink};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Knobs of the online run that are not trait objects.
#[derive(Debug, Clone, Copy, Default)]
pub struct OnlineOpts {
    /// Modeled scheduling overhead: virtual milliseconds charged per
    /// order evaluation the reorder decision spends. A closed window
    /// cannot start service before `close + evals × this` — set it > 0
    /// to make the search budget a *latency* trade-off instead of a free
    /// lunch. Default 0 (decisions are instantaneous, only bounded by
    /// their evaluation budget). Negative or non-finite values are
    /// treated as 0 — time only moves forward.
    pub decision_ms_per_eval: f64,
}

/// Totally ordered f64 for the completion heap (event times are always
/// finite).
#[derive(Debug, Clone, Copy, PartialEq)]
struct EventTime(f64);

impl Eq for EventTime {}

impl PartialOrd for EventTime {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EventTime {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// A kernel waiting in the open reorder window.
struct Open {
    id: u64,
    arrival_ms: f64,
    profile: KernelProfile,
}

/// A closed window queued behind the device.
struct Closed {
    batch: u64,
    close_ms: f64,
    /// Close time plus decision overhead; service cannot start earlier.
    ready_ms: f64,
    members: Vec<Open>,
    order: Vec<usize>,
    evals: u64,
}

/// Event priorities at equal times (lower wins).
const EV_COMPLETION: u8 = 0;
const EV_BATCH_START: u8 = 1;
const EV_ARRIVAL: u8 = 2;
const EV_RECHECK: u8 = 3;

/// Run the online scheduler over one arrival stream. See the module docs
/// for the event model; the returned [`OnlineReport`] carries every
/// per-kernel timestamp. Equivalent to
/// [`simulate_online_with_admission`] under the `none` policy
/// (bit-identical — pinned in `tests/overload_protection.rs`).
pub fn simulate_online(
    gpu: &GpuSpec,
    source: Box<dyn ArrivalSource>,
    window: Box<dyn WindowPolicy>,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    opts: &OnlineOpts,
) -> OnlineReport {
    let mut none = NoAdmission;
    simulate_online_with_admission(gpu, source, window, reorderer, make_backend, opts, &mut none)
}

/// [`simulate_online`] with an [`AdmissionPolicy`] gating arrivals at
/// the virtual clock. A rejected arrival never enters the open window:
/// it becomes a first-class [`ShedRecord`] with a
/// [`ShedCause::Rejected`] cause and its source is notified
/// (`on_completion`) so closed-loop clients never starve. The extended
/// conservation invariant is `kernels.len() + shed.len() == arrivals`.
///
/// When the policy [`is_noop`](AdmissionPolicy::is_noop) (the `none`
/// spelling) the entire gate is skipped — no occupancy snapshot, no
/// backlog pricing, no float arithmetic — so `none` runs are
/// **bit-identical** to [`simulate_online`]. Equivalent to
/// [`simulate_online_traced`] under the [`NoTrace`] sink.
pub fn simulate_online_with_admission(
    gpu: &GpuSpec,
    source: Box<dyn ArrivalSource>,
    window: Box<dyn WindowPolicy>,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    opts: &OnlineOpts,
    admission: &mut dyn AdmissionPolicy,
) -> OnlineReport {
    let mut sink = NoTrace;
    simulate_online_traced(
        gpu,
        source,
        window,
        reorderer,
        make_backend,
        opts,
        admission,
        &mut sink,
    )
}

/// [`simulate_online_with_admission`] with a [`TraceSink`] observing
/// every decision point: arrival, admission verdict, window close/wait,
/// reorder decision (with chosen-vs-FIFO makespans recomputed on a
/// fresh backend), batch start/finish and shed. The sink **observes,
/// never perturbs**: all event construction sits behind one
/// `!sink.is_noop()` branch, so runs under [`NoTrace`] are bit-identical
/// and allocation-free versus the untraced entry points (which delegate
/// here — pinned in `tests/trace_observability.rs`), and recorded
/// streams are bit-deterministic per (seed, config).
///
/// [`TraceEvent::BatchFinish`] is emitted when the batch *starts* (the
/// virtual-clock engine knows the makespan then) and stamped with the
/// future finish time, so a stream's finish stamps can interleave with
/// later-emitted, earlier-stamped events; consumers that need per-lane
/// monotonicity reconstruct spans ([`crate::obs::export`]).
#[allow(clippy::too_many_arguments)]
pub fn simulate_online_traced(
    gpu: &GpuSpec,
    mut source: Box<dyn ArrivalSource>,
    mut window: Box<dyn WindowPolicy>,
    reorderer: &OnlineReorderer,
    make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    opts: &OnlineOpts,
    admission: &mut dyn AdmissionPolicy,
    sink: &mut dyn TraceSink,
) -> OnlineReport {
    let traced = !sink.is_noop();
    let mut backend = make_backend();
    let admission_name = admission.name();
    let gate_active = !admission.is_noop();
    let admission_pricing = gate_active && admission.needs_pricing();
    let source_name = source.name();
    let window_name = window.name();
    // A negative decision cost would move batch-ready times before their
    // close times and break event monotonicity; clamp it out.
    let decision_ms_per_eval = if opts.decision_ms_per_eval.is_finite() {
        opts.decision_ms_per_eval.max(0.0)
    } else {
        0.0
    };

    let mut now = 0.0f64;
    let mut pending: Vec<Open> = Vec::new();
    let mut queue: VecDeque<Closed> = VecDeque::new();
    // Min-heap of (finish time, kernel id) completion events.
    let mut completions: BinaryHeap<Reverse<(EventTime, u64)>> = BinaryHeap::new();
    let mut device_free_at = 0.0f64;
    let mut next_batch = 0u64;

    let mut kernels: Vec<KernelRecord> = Vec::new();
    let mut batches: Vec<BatchRecord> = Vec::new();
    let mut device_busy_ms = 0.0f64;
    let mut decision_evals = 0u64;
    let mut n_unsimulable = 0usize;
    let mut n_degraded_decisions = 0u64;
    let mut n_shed_kernels = 0usize;
    let mut shed: Vec<ShedRecord> = Vec::new();

    loop {
        // Ask the policy about the open window. Closing never advances
        // time, so the policy always sees the post-close state before
        // the clock moves again.
        let mut close_now = false;
        let mut recheck_at: Option<f64> = None;
        if !pending.is_empty() {
            let state = WindowState {
                now_ms: now,
                n_pending: pending.len(),
                oldest_arrival_ms: pending[0].arrival_ms,
                device_free_at_ms: device_free_at,
                queued_batches: queue.len(),
            };
            match window.decide(&state) {
                WindowDecision::Close => close_now = true,
                WindowDecision::Wait { recheck_at_ms } => {
                    debug_assert!(
                        recheck_at_ms.map_or(true, |t| t > now),
                        "window policy returned a non-future recheck deadline"
                    );
                    recheck_at = recheck_at_ms;
                }
            }
            if traced {
                sink.record(TraceEvent::WindowDecide {
                    t_ms: now,
                    device: 0,
                    n_pending: pending.len(),
                    queued_batches: queue.len(),
                    close: close_now,
                });
            }
        }

        if !close_now {
            // Earliest event, ties broken by the fixed priority order.
            let t_completion = completions.peek().map(|Reverse((t, _))| t.0);
            let t_start = queue.front().map(|b| b.ready_ms.max(device_free_at));
            let t_arrival = source.next_at();
            let candidates = [
                (t_completion, EV_COMPLETION),
                (t_start, EV_BATCH_START),
                (t_arrival, EV_ARRIVAL),
                (recheck_at, EV_RECHECK),
            ];
            let mut next: Option<(f64, u8)> = None;
            for (t, kind) in candidates {
                let Some(t) = t else { continue };
                let better = match next {
                    None => true,
                    Some((bt, bk)) => t < bt || (t == bt && kind < bk),
                };
                if better {
                    next = Some((t, kind));
                }
            }

            match next {
                None if pending.is_empty() => break, // drained and idle: done
                // End-of-stream drain: nothing else can ever happen, so
                // the window closes regardless of the policy (a
                // fixed:<k> window would otherwise strand its remainder
                // forever).
                None => close_now = true,
                Some((t, kind)) => {
                    debug_assert!(t >= now, "event time moved backwards");
                    now = t.max(now);
                    match kind {
                        EV_COMPLETION => {
                            let Reverse((_, id)) = completions.pop().expect("peeked");
                            source.on_completion(now, id);
                        }
                        EV_BATCH_START => {
                            let b = queue.pop_front().expect("peeked");
                            let profiles: Vec<KernelProfile> =
                                b.members.iter().map(|m| m.profile.clone()).collect();
                            let report = backend.execute(gpu, &profiles, &b.order);
                            let makespan = if report.makespan_ms.is_nan() {
                                // Unsimulable batch: serve it in zero
                                // time rather than wedging the queue
                                // (validated sources never hit this; the
                                // report counts it). Its kernels got no
                                // real service — they are force-dropped,
                                // the single-device shed counter.
                                n_unsimulable += 1;
                                n_shed_kernels += b.members.len();
                                0.0
                            } else {
                                report.makespan_ms
                            };
                            device_free_at = now + makespan;
                            device_busy_ms += makespan;
                            if traced {
                                sink.record(TraceEvent::BatchStart {
                                    t_ms: now,
                                    device: 0,
                                    batch: b.batch,
                                    n: b.members.len(),
                                    order: b.order.clone(),
                                });
                                sink.record(TraceEvent::BatchFinish {
                                    t_ms: now + makespan,
                                    device: 0,
                                    batch: b.batch,
                                    makespan_ms: makespan,
                                });
                            }
                            for o in &report.outcomes {
                                let m = &b.members[o.index];
                                let dt = if o.finish_ms.is_nan() { 0.0 } else { o.finish_ms };
                                let finish = now + dt;
                                kernels.push(KernelRecord {
                                    id: m.id,
                                    arrival_ms: m.arrival_ms,
                                    close_ms: b.close_ms,
                                    start_ms: now,
                                    finish_ms: finish,
                                    batch: b.batch,
                                    position: o.position,
                                });
                                completions.push(Reverse((EventTime(finish), m.id)));
                            }
                            batches.push(BatchRecord {
                                id: b.batch,
                                n: b.members.len(),
                                close_ms: b.close_ms,
                                ready_ms: b.ready_ms,
                                start_ms: now,
                                makespan_ms: makespan,
                                evals: b.evals,
                                order: b.order,
                            });
                        }
                        EV_ARRIVAL => {
                            let a = source.pop(now);
                            if traced {
                                sink.record(TraceEvent::Arrival { t_ms: now, id: a.id });
                            }
                            // Admission gate: skipped entirely under
                            // `none` (bit-identity), priced only when
                            // the policy asks for it.
                            let admit = if gate_active {
                                let queued: usize =
                                    queue.iter().map(|b| b.members.len()).sum();
                                let depth = pending.len() + queued + completions.len();
                                let mut oldest = f64::INFINITY;
                                for m in &pending {
                                    oldest = oldest.min(m.arrival_ms);
                                }
                                for b in &queue {
                                    for m in &b.members {
                                        oldest = oldest.min(m.arrival_ms);
                                    }
                                }
                                let oldest_wait_ms = if oldest.is_finite() {
                                    (now - oldest).max(0.0)
                                } else {
                                    0.0
                                };
                                let predicted_sojourn_ms = if admission_pricing {
                                    // Admissible lower bound on this
                                    // arrival's sojourn: residual busy
                                    // time + the backend's suffix bound
                                    // over the backlog plus the arrival
                                    // itself (mirrors the fleet engine's
                                    // `price_backlog`).
                                    let residual = (device_free_at - now).max(0.0);
                                    let mut profiles: Vec<KernelProfile> =
                                        pending.iter().map(|m| m.profile.clone()).collect();
                                    for b in &queue {
                                        profiles
                                            .extend(b.members.iter().map(|m| m.profile.clone()));
                                    }
                                    profiles.push(a.profile.clone());
                                    let all: Vec<usize> = (0..profiles.len()).collect();
                                    let mut prepared = backend.prepare(gpu, &profiles);
                                    let lb = prepared.suffix_lower_bound(&all);
                                    residual + if lb.is_finite() { lb.max(0.0) } else { 0.0 }
                                } else {
                                    f64::NAN
                                };
                                let ok = admission.admit(&AdmissionState {
                                    now_ms: now,
                                    queue_depth: depth,
                                    oldest_wait_ms,
                                    predicted_sojourn_ms,
                                });
                                if traced {
                                    sink.record(TraceEvent::Admission {
                                        t_ms: now,
                                        id: a.id,
                                        policy: admission_name.clone(),
                                        admitted: ok,
                                        queue_depth: depth,
                                        predicted_sojourn_ms,
                                    });
                                }
                                ok
                            } else {
                                true
                            };
                            if admit {
                                pending.push(Open {
                                    id: a.id,
                                    arrival_ms: a.at_ms,
                                    profile: a.profile,
                                });
                            } else {
                                let cause = ShedCause::Rejected {
                                    policy: admission_name.clone(),
                                };
                                if traced {
                                    sink.record(TraceEvent::Shed {
                                        t_ms: now,
                                        id: a.id,
                                        cause: cause.to_csv(),
                                    });
                                }
                                shed.push(ShedRecord {
                                    id: a.id,
                                    arrival_ms: a.at_ms,
                                    attempts: 0,
                                    cause,
                                });
                                // The kernel left the system: closed-loop
                                // sources must not wait for it forever.
                                source.on_completion(now, a.id);
                            }
                        }
                        _ => {} // EV_RECHECK: the policy re-decides above
                    }
                    continue;
                }
            }
        }

        // Close the open window: reorder within the per-decision budget
        // and queue the batch behind the device.
        let members = std::mem::take(&mut pending);
        let profiles: Vec<KernelProfile> = members.iter().map(|m| m.profile.clone()).collect();
        let decision = reorderer.decide(gpu, &profiles, make_backend);
        decision_evals += decision.evals;
        if decision.degraded {
            n_degraded_decisions += 1;
        }
        if traced && !profiles.is_empty() {
            // Price the chosen order and FIFO on a *fresh* backend:
            // observation only, nothing the engine later uses.
            let mut fresh = make_backend();
            let mut prepared = fresh.prepare(gpu, &profiles);
            let chosen_ms = prepared.execute_order(&decision.order);
            let identity: Vec<usize> = (0..profiles.len()).collect();
            let fifo_ms = prepared.execute_order(&identity);
            sink.record(TraceEvent::ReorderDecision {
                t_ms: now,
                device: 0,
                batch: next_batch,
                n: profiles.len(),
                strategy: reorderer.name(),
                evals: decision.evals,
                degraded: decision.degraded,
                chosen_ms,
                fifo_ms,
            });
        }
        queue.push_back(Closed {
            batch: next_batch,
            close_ms: now,
            ready_ms: now + decision_ms_per_eval * decision.evals as f64,
            members,
            order: decision.order,
            evals: decision.evals,
        });
        next_batch += 1;
    }

    let span_ms = kernels.iter().map(|k| k.finish_ms).fold(0.0, f64::max);
    kernels.sort_by_key(|k| k.id);
    shed.sort_by_key(|s| s.id);
    OnlineReport {
        source: source_name,
        window: window_name,
        reorderer: reorderer.name(),
        backend: backend.name().to_string(),
        admission: admission_name,
        kernels,
        batches,
        span_ms,
        device_busy_ms,
        decision_evals,
        n_unsimulable,
        n_degraded_decisions,
        n_shed_kernels,
        shed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SimulatorBackend;
    use crate::online::arrivals::{ReplaySource, Trace};
    use crate::online::window::parse_window_policy;
    use crate::workloads::scenario_by_id;

    fn sim() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
        Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
    }

    fn run(
        family: &str,
        n: usize,
        rate: f64,
        window: &str,
        reorderer: &OnlineReorderer,
    ) -> OnlineReport {
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson(family, n, rate, 7);
        let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
        let w = parse_window_policy(window).unwrap();
        simulate_online(&gpu, source, w, reorderer, sim().as_ref(), &OnlineOpts::default())
    }

    #[test]
    fn conservation_and_timestamp_ordering() {
        let r = run("uniform", 24, 100.0, "linger:6:30", &OnlineReorderer::fifo());
        assert_eq!(r.kernels.len(), 24);
        assert_eq!(r.batches.iter().map(|b| b.n).sum::<usize>(), 24);
        // Every batch holds at least one kernel — a zero-kernel window is
        // a scheduler bug.
        assert!(r.batches.iter().all(|b| b.n >= 1));
        let ids: Vec<u64> = r.kernels.iter().map(|k| k.id).collect();
        assert_eq!(ids, (0..24).collect::<Vec<_>>());
        for k in &r.kernels {
            assert!(k.arrival_ms <= k.close_ms, "{k:?}");
            assert!(k.close_ms <= k.start_ms, "{k:?}");
            assert!(k.start_ms <= k.finish_ms, "{k:?}");
        }
        // The device is serial: each batch starts only after the
        // previous one finished.
        for w in r.batches.windows(2) {
            assert!(w[1].start_ms >= w[0].start_ms + w[0].makespan_ms - 1e-9);
        }
        assert!(r.span_ms > 0.0);
        assert_eq!(r.n_unsimulable, 0);
    }

    #[test]
    fn fixed_window_batches_exactly_k_plus_drain_remainder() {
        let r = run("uniform", 14, 200.0, "fixed:4", &OnlineReorderer::fifo());
        let sizes: Vec<usize> = r.batches.iter().map(|b| b.n).collect();
        assert_eq!(sizes, vec![4, 4, 4, 2]);
    }

    #[test]
    fn sparse_arrivals_with_linger_serve_singletons() {
        // Inter-arrival ~20 s (far beyond any single-kernel makespan),
        // linger 5 ms, huge cap: every kernel rides alone — the latency
        // SLO wins over batching.
        let r = run("uniform", 6, 0.05, "linger:64:5", &OnlineReorderer::fifo());
        assert!(r.batches.iter().all(|b| b.n == 1), "{:?}", r.batches);
        // With the device idle between sparse arrivals, no kernel waits
        // past the linger bound.
        for (k, q) in r.kernels.iter().zip(r.queue_waits_ms()) {
            assert!(q <= 5.0 + 1e-9, "{k:?} waited {q}");
        }
    }

    #[test]
    fn adaptive_window_grows_under_load() {
        let idle = run("uniform", 24, 0.05, "adaptive:8:40", &OnlineReorderer::fifo());
        let loaded = run("uniform", 24, 2000.0, "adaptive:8:40", &OnlineReorderer::fifo());
        assert!(
            loaded.mean_window() > idle.mean_window(),
            "loaded {} !> idle {}",
            loaded.mean_window(),
            idle.mean_window()
        );
        assert!(idle.mean_window() < 2.0, "idle windows should stay small");
    }

    #[test]
    fn decision_cost_delays_service() {
        let gpu = GpuSpec::gtx580();
        let reorderer = OnlineReorderer::search("local:0", 64).unwrap();
        let trace = Trace::poisson("skewed", 16, 500.0, 3);
        let mut spans = Vec::new();
        for cost in [0.0, 0.05] {
            let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
            let w = parse_window_policy("linger:8:20").unwrap();
            let opts = OnlineOpts {
                decision_ms_per_eval: cost,
            };
            let r = simulate_online(&gpu, source, w, &reorderer, sim().as_ref(), &opts);
            // ready_ms reflects the charged overhead.
            for b in &r.batches {
                assert!((b.ready_ms - b.close_ms - cost * b.evals as f64).abs() < 1e-9);
            }
            spans.push(r.span_ms);
        }
        assert!(spans[1] > spans[0], "overhead {spans:?} did not delay completion");
    }

    #[test]
    fn closed_loop_couples_arrivals_to_completions() {
        let gpu = GpuSpec::gtx580();
        let fam = scenario_by_id("uniform").unwrap();
        let source = Box::new(crate::online::ClosedLoopSource::new(fam, &gpu, 12, 3, 1.0, 9));
        let w = parse_window_policy("adaptive:4:10").unwrap();
        let r = simulate_online(
            &gpu,
            source,
            w,
            &OnlineReorderer::fifo(),
            sim().as_ref(),
            &OnlineOpts::default(),
        );
        assert_eq!(r.kernels.len(), 12);
        // With 3 clients, no window can ever hold more than 3 kernels.
        assert!(r.batches.iter().all(|b| b.n <= 3), "{:?}", r.batches);
        // Later kernels arrive only after earlier completions: arrivals
        // interleave with finishes rather than all landing at t≈0.
        let last_arrival = r.kernels.iter().map(|k| k.arrival_ms).fold(0.0, f64::max);
        let first_finish = r.kernels.iter().map(|k| k.finish_ms).fold(f64::INFINITY, f64::min);
        assert!(last_arrival > first_finish);
    }

    #[test]
    fn bound_admission_sheds_overload_and_conserves_arrivals() {
        let gpu = GpuSpec::gtx580();
        let trace = Trace::poisson("uniform", 24, 2000.0, 7);
        let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
        let w = parse_window_policy("linger:6:30").unwrap();
        let mut adm = crate::admission::parse_admission_policy("bound:2").unwrap();
        let r = simulate_online_with_admission(
            &gpu,
            source,
            w,
            &OnlineReorderer::fifo(),
            sim().as_ref(),
            &OnlineOpts::default(),
            adm.as_mut(),
        );
        // Conservation: every arrival is served or shed, never neither.
        assert_eq!(r.kernels.len() + r.shed.len(), 24);
        assert!(!r.shed.is_empty(), "a 2-deep bound under burst load must shed");
        let mut ids: Vec<u64> = r
            .kernels
            .iter()
            .map(|k| k.id)
            .chain(r.shed.iter().map(|s| s.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..24).collect::<Vec<_>>());
        assert_eq!(r.admission, "bound:2");
        for s in &r.shed {
            assert_eq!(s.attempts, 0);
            assert!(s.cause.to_string().contains("bound:2"), "{:?}", s.cause);
        }
    }

    #[test]
    fn closed_loop_sources_survive_admission_rejections() {
        // A rejected kernel must still notify its closed-loop client,
        // or the client would wait forever and the run would wedge.
        let gpu = GpuSpec::gtx580();
        let fam = scenario_by_id("uniform").unwrap();
        let source = Box::new(crate::online::ClosedLoopSource::new(fam, &gpu, 12, 3, 1.0, 9));
        let w = parse_window_policy("fixed:1").unwrap();
        let mut adm = crate::admission::parse_admission_policy("bound:1").unwrap();
        let r = simulate_online_with_admission(
            &gpu,
            source,
            w,
            &OnlineReorderer::fifo(),
            sim().as_ref(),
            &OnlineOpts::default(),
            adm.as_mut(),
        );
        // All 12 issued submissions are accounted for.
        assert_eq!(r.kernels.len() + r.shed.len(), 12);
        assert!(!r.kernels.is_empty());
    }

    #[test]
    fn report_is_sorted_by_id_and_span_matches_max_finish() {
        let r = run("mixed", 20, 300.0, "linger:8:25", &OnlineReorderer::fifo());
        for w in r.kernels.windows(2) {
            assert!(w[0].id < w[1].id);
        }
        let max_finish = r.kernels.iter().map(|k| k.finish_ms).fold(0.0, f64::max);
        assert_eq!(r.span_ms.to_bits(), max_finish.to_bits());
    }
}
