//! **Online streaming scheduler** — arrival-driven reorder windows with
//! latency SLOs.
//!
//! Every offline layer of this crate ([`crate::perm`], [`crate::search`],
//! the batch coordinator) assumes the kernels are already in hand. A
//! production service sees a *stream*: launch requests arrive over time,
//! and the scheduler must trade reordering freedom (bigger windows =
//! better orders) against the latency each queued kernel pays for the
//! wait. This module couples the existing per-order evaluation seams to
//! a clock:
//!
//! * [`arrivals`](self::arrivals) — seeded arrival processes (`poisson`,
//!   `bursty`, `closed` loop, `replay` of a recorded [`Trace`]) drawing
//!   kernels from the [`crate::workloads::Scenario`] families;
//! * [`window`](self::window) — pluggable [`WindowPolicy`] deciding
//!   *when* a reorder window closes (`fixed`, `linger` with its latency
//!   bound, occupancy-aware `adaptive`);
//! * [`OnlineReorderer`] — decides *what order* a closed window launches
//!   in: exhaustive for tiny windows (when the evaluation budget provably
//!   covers `n!`), any registered anytime [`crate::search::SearchStrategy`]
//!   beyond, always under a per-decision [`crate::search::SearchBudget`]
//!   so scheduling overhead is bounded — and never worse than the FIFO
//!   arrival order (a final guarded comparison). A within-window
//!   dependency template ([`OnlineReorderer::with_deps`]) constrains
//!   every decision to topological orders; template edges point forward
//!   in arrival order, so FIFO stays feasible and the guard unchanged;
//! * [`simulate_online`] — the deterministic virtual-clock event loop
//!   (no wall sleeping; bit-identical per-kernel timestamps per seed);
//! * [`report`](self::report) — per-kernel queue-wait / service /
//!   sojourn accounting with exact p50/p95/p99, plus throughput,
//!   utilization and SLO attainment;
//! * [`oracle`](self::oracle) — the clairvoyant full-trace baseline that
//!   prices onlineness per arrival regime.
//!
//! The thread coordinator ([`crate::coordinator`]) shares the
//! [`WindowPolicy`] seam for its dispatcher batching, so a policy tuned
//! in simulation drops into the real service unchanged — including
//! occupancy-aware policies, which read live per-device queue depths
//! through [`WindowState::queued_batches`] there (see
//! [`crate::coordinator::CoordinatorBuilder::window_policy`]). The
//! multi-device generalization lives in [`crate::fleet`]: a
//! [`crate::fleet::RoutePolicy`] in front of per-device window +
//! reorder loops. CLI:
//! `kreorder serve --arrivals poisson:<rate>:<seed> --window <policy>
//! --strategy <s>`; CI trends FIFO-vs-reordered tail latency through
//! `benches/online_latency.rs` (`BENCH_online.json`).
//!
//! ```
//! use kreorder::gpu::GpuSpec;
//! use kreorder::exec::{ExecutionBackend, SimulatorBackend};
//! use kreorder::online::{
//!     parse_window_policy, simulate_online, OnlineOpts, OnlineReorderer, ReplaySource, Trace,
//! };
//!
//! let gpu = GpuSpec::gtx580();
//! let trace = Trace::poisson("skewed", 24, 200.0, 7);
//! let source = Box::new(ReplaySource::from_trace(&trace, &gpu).unwrap());
//! let window = parse_window_policy("linger:8:50").unwrap();
//! let reorderer = OnlineReorderer::search("local:0", 256).unwrap();
//! let report = simulate_online(
//!     &gpu,
//!     source,
//!     window,
//!     &reorderer,
//!     &|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>,
//!     &OnlineOpts::default(),
//! );
//! assert_eq!(report.kernels.len(), 24);
//! println!("p99 sojourn: {:.2} ms", report.sojourn_stats().p99_ms);
//! ```

pub mod arrivals;
mod engine;
pub mod oracle;
pub mod report;
pub mod window;

pub use arrivals::{
    arrival_help_table, Arrival, ArrivalParseError, ArrivalSource, ArrivalSpec, ClosedLoopSource,
    ReplaySource, Trace, TraceParseError,
};
pub use engine::{
    simulate_online, simulate_online_traced, simulate_online_with_admission, OnlineOpts,
};
pub use oracle::{
    fifo_window_capacity_per_s, offline_oracle, OracleOutcome, ORACLE_EXACT_MAX_N,
};
pub use report::{
    shed_csv, BatchRecord, KernelRecord, LatencyStats, OnlineReport, ShedCause, ShedRecord,
};
pub use window::{
    parse_window_policy, window_policy_help_table, AdaptiveWindow, FixedWindow, LingerWindow,
    WindowDecision, WindowParseError, WindowPolicy, WindowState,
};

use crate::exec::ExecutionBackend;
use crate::gpu::{GpuSpec, KernelProfile};
use crate::perm::{sweep_dag_with, sweep_with};
use crate::search::{exact_tree_evals, improves, parse_strategy, SearchBudget};
use crate::workloads::{DepGraph, Workload, MAX_DAG_KERNELS};
use std::fmt;

/// Largest window the [`OnlineReorderer`] will solve exhaustively even
/// when the evaluation budget covers `n!` — 8! = 40 320 evaluations
/// (~300 KB of sweep state) is cheap; beyond it the anytime strategies
/// are both faster and allocation-bounded.
pub const ONLINE_EXACT_MAX_N: usize = 8;

/// What one reorder decision chose.
#[derive(Debug, Clone)]
pub struct ReorderDecision {
    /// Launch order: a permutation of `0..n` batch positions.
    pub order: Vec<usize>,
    /// Order evaluations the decision spent (0 for FIFO).
    pub evals: u64,
    /// The decision fell back to FIFO arrival order *after* spending
    /// search budget (the FIFO guard rejected the searched order) — the
    /// graceful-degradation signal the engines count. Plain FIFO mode
    /// and tiny windows are not degraded: FIFO was the plan, not the
    /// fallback.
    pub degraded: bool,
}

/// Per-window order selection for the online engine.
///
/// Determinism contract (the whole subsystem's replay guarantee rests on
/// it): a decision is a pure function of `(mode, kernels)`. The exact
/// path is the exhaustive [`crate::perm::sweep_with`] — used only when
/// the budget provably covers all `n!` orders, so its evaluation count
/// is `n!` exactly, never a run-dependent pruning count — and the
/// anytime path is a seeded sequential strategy whose trajectory is
/// reproducible from `(seed, evals)`. Budget-capped parallel
/// branch-and-bound is rejected at construction for the same reason
/// [`crate::search::SearchPolicy`] rejects it.
#[derive(Debug, Clone)]
pub struct OnlineReorderer {
    mode: ReorderMode,
    /// Within-window dependency template: edge `(pred, succ)` constrains
    /// every window to launch batch position `pred` before `succ`.
    /// Validated `pred < succ` at construction, so the FIFO arrival
    /// order (the identity permutation) is a topological order of every
    /// window the template induces — the FIFO fallback and the FIFO
    /// guard below stay feasible unchanged. Edges whose `succ` does not
    /// fit a given window are ignored for that window.
    deps: Vec<(usize, usize)>,
}

#[derive(Debug, Clone)]
enum ReorderMode {
    Fifo,
    Search { strategy: String, budget_evals: u64 },
}

/// Error constructing an [`OnlineReorderer`] from a strategy spelling.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReordererParseError {
    pub input: String,
    reason: String,
}

impl fmt::Display for ReordererParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid online reorder strategy `{}`: {}", self.input, self.reason)
    }
}

impl std::error::Error for ReordererParseError {}

impl OnlineReorderer {
    /// No reordering: every window launches in arrival order. The
    /// baseline the bench gates compare against.
    pub fn fifo() -> Self {
        OnlineReorderer {
            mode: ReorderMode::Fifo,
            deps: Vec::new(),
        }
    }

    /// Budgeted search per window: exhaustive when `n!` provably fits
    /// `budget_evals`, the given anytime strategy (`"anneal:<seed>"`,
    /// `"local:<seed>"`) beyond. `"bnb"` is rejected — a budget-capped
    /// parallel solve is not run-to-run deterministic, and the exact
    /// path is chosen automatically where it is affordable.
    pub fn search(strategy: &str, budget_evals: u64) -> Result<Self, ReordererParseError> {
        let parsed = parse_strategy(strategy).map_err(|e| ReordererParseError {
            input: strategy.into(),
            reason: e.to_string(),
        })?;
        if parsed.name() == "bnb" {
            return Err(ReordererParseError {
                input: strategy.into(),
                reason: "budget-capped parallel branch-and-bound is not deterministic; \
                         exhaustive search is already used automatically when the budget \
                         covers the window"
                    .into(),
            });
        }
        Ok(OnlineReorderer {
            mode: ReorderMode::Search {
                strategy: parsed.name(),
                budget_evals,
            },
            deps: Vec::new(),
        })
    }

    /// Attach a within-window dependency template: every decided window
    /// must launch batch position `pred` before `succ` for each edge
    /// `(pred, succ)`. Edges must satisfy `pred < succ` — dependencies
    /// that point *backwards* in arrival order would make the FIFO
    /// fallback infeasible (a window cannot launch a successor that
    /// arrived before its predecessor and still serve arrival order),
    /// so they are rejected here rather than silently dropped. An empty
    /// template leaves every decision bit-identical to the undecorated
    /// reorderer.
    pub fn with_deps(mut self, edges: &[(usize, usize)]) -> Result<Self, ReordererParseError> {
        for &(pred, succ) in edges {
            if pred >= succ {
                return Err(ReordererParseError {
                    input: format!("{pred}->{succ}"),
                    reason: format!(
                        "window dependency edges must point forward in arrival order \
                         (pred < succ); `{pred}->{succ}` would make the FIFO arrival \
                         order infeasible"
                    ),
                });
            }
            if succ >= MAX_DAG_KERNELS {
                return Err(ReordererParseError {
                    input: format!("{pred}->{succ}"),
                    reason: format!(
                        "window dependency edge `{pred}->{succ}` references batch \
                         position {succ}, but the dependency model caps windows at \
                         {MAX_DAG_KERNELS} kernels (positions 0..{MAX_DAG_KERNELS})"
                    ),
                });
            }
        }
        self.deps = edges.to_vec();
        Ok(self)
    }

    /// Build the dependency graph the template induces on a window of
    /// `n` kernels: edges whose successor fits the window, validated.
    /// Returns `None` when no edge applies (the plain, dependency-free
    /// decision path must run — bit-identical to an empty template).
    fn window_graph(&self, n: usize) -> Option<(Vec<(usize, usize)>, DepGraph)> {
        if self.deps.is_empty() || n < 2 {
            return None;
        }
        let edges: Vec<(usize, usize)> = self
            .deps
            .iter()
            .copied()
            .filter(|&(_, succ)| succ < n)
            .collect();
        if edges.is_empty() {
            return None;
        }
        let graph = DepGraph::build(n, &edges)
            .expect("pred < succ edges within the window are always acyclic");
        Some((edges, graph))
    }

    /// Display spelling (`"fifo"` or `"search:<strategy>:<budget>"`,
    /// with a `+deps:<edges>` suffix when a dependency template is
    /// attached).
    pub fn name(&self) -> String {
        let base = match &self.mode {
            ReorderMode::Fifo => "fifo".to_string(),
            ReorderMode::Search {
                strategy,
                budget_evals,
            } => format!("search:{strategy}:{budget_evals}"),
        };
        if self.deps.is_empty() {
            base
        } else {
            format!("{base}+deps:{}", self.deps.len())
        }
    }

    /// Pick a launch order for one closed window.
    pub fn decide(
        &self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
    ) -> ReorderDecision {
        let n = kernels.len();
        let fifo: Vec<usize> = (0..n).collect();
        let (strategy, budget_evals) = match &self.mode {
            ReorderMode::Fifo => {
                return ReorderDecision {
                    order: fifo,
                    evals: 0,
                    degraded: false,
                }
            }
            ReorderMode::Search {
                strategy,
                budget_evals,
            } => (strategy, *budget_evals),
        };
        if n <= 1 {
            return ReorderDecision {
                order: fifo,
                evals: 0,
                degraded: false,
            };
        }

        // A dependency template that applies to this window constrains
        // the decision to topological orders. Empty / inapplicable
        // templates fall through to the plain path below unchanged.
        if let Some((edges, graph)) = self.window_graph(n) {
            return self.decide_dag(
                gpu,
                kernels,
                edges,
                &graph,
                strategy,
                budget_evals,
                make_backend,
                fifo,
            );
        }

        // Tiny windows, fully covered budget: exhaustive sweep. Exactly
        // n! evaluations, optimum provable, FIFO dominated by
        // construction (the sweep evaluates it too). The window-size cap
        // keeps a generous budget from routing a large window to an
        // n!-sized sweep allocation.
        if n <= ONLINE_EXACT_MAX_N
            && exact_tree_evals(n).is_some_and(|need| need <= budget_evals)
        {
            let sw = sweep_with(gpu, kernels, make_backend);
            let evals = sw.n_perms as u64;
            let order = if sw.best_order.len() == n { sw.best_order } else { fifo };
            return ReorderDecision {
                order,
                evals,
                degraded: false,
            };
        }

        // Anytime search under the per-decision budget…
        let parsed = parse_strategy(strategy).expect("validated at construction");
        let out = parsed.search(
            gpu,
            kernels,
            make_backend,
            &SearchBudget::evals(budget_evals),
        );
        let mut evals = out.evals;
        if out.best_order.len() != n {
            // The strategy failed to produce a full order: a degraded
            // FIFO fallback.
            return ReorderDecision {
                order: fifo,
                evals,
                degraded: true,
            };
        }
        // …with a FIFO guard: the served order is never worse than
        // arrival order (ties break toward FIFO, the lexicographically
        // smaller permutation), so enabling search can only help the
        // makespan of any window it touches.
        let mut backend = make_backend();
        let mut prepared = backend.prepare(gpu, kernels);
        let t_cand = prepared.execute_order(&out.best_order);
        let t_fifo = prepared.execute_order(&fifo);
        evals += 2;
        if improves(t_cand, &out.best_order, t_fifo, &fifo) {
            ReorderDecision {
                order: out.best_order,
                evals,
                degraded: false,
            }
        } else {
            // Budget spent, search did not beat arrival order: serve
            // FIFO and let the report count the degraded decision.
            ReorderDecision {
                order: fifo,
                evals,
                degraded: true,
            }
        }
    }

    /// Dependency-constrained twin of the tail of [`decide`](Self::decide):
    /// exhaustive over the window's *linear extensions* when the budget
    /// provably covers them, dependency-aware anytime search beyond. The
    /// FIFO guard is unchanged — arrival order is a topological order of
    /// every template-induced window (edges point forward by
    /// construction), so falling back to it never violates a dependency.
    #[allow(clippy::too_many_arguments)]
    fn decide_dag(
        &self,
        gpu: &GpuSpec,
        kernels: &[KernelProfile],
        edges: Vec<(usize, usize)>,
        graph: &DepGraph,
        strategy: &str,
        budget_evals: u64,
        make_backend: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync),
        fifo: Vec<usize>,
    ) -> ReorderDecision {
        let n = kernels.len();

        // Covered exact path: the extension count plays the role n!
        // plays in the unconstrained branch — the sweep enumerates only
        // topological orders, so that is the exact evaluation bill.
        if n <= ONLINE_EXACT_MAX_N {
            if let Some(ext) = graph.linear_extension_count() {
                if ext <= budget_evals as u128 {
                    let sw = sweep_dag_with(gpu, kernels, graph, make_backend);
                    let evals = sw.n_perms as u64;
                    let order = if sw.best_order.len() == n {
                        sw.best_order
                    } else {
                        fifo
                    };
                    return ReorderDecision {
                        order,
                        evals,
                        degraded: false,
                    };
                }
            }
        }

        // Anytime dependency-aware search under the per-decision budget…
        let parsed = parse_strategy(strategy).expect("validated at construction");
        let workload = Workload::new(kernels.to_vec(), edges);
        let out = parsed.search_dag(
            gpu,
            &workload,
            make_backend,
            &SearchBudget::evals(budget_evals),
        );
        let mut evals = out.evals;
        if out.best_order.len() != n || !graph.is_topological(&out.best_order) {
            // No full feasible order out of the strategy: degraded FIFO
            // fallback (always feasible — see above).
            return ReorderDecision {
                order: fifo,
                evals,
                degraded: true,
            };
        }
        // …with the same FIFO guard as the unconstrained path.
        let mut backend = make_backend();
        let mut prepared = backend.prepare(gpu, kernels);
        let t_cand = prepared.execute_order(&out.best_order);
        let t_fifo = prepared.execute_order(&fifo);
        evals += 2;
        if improves(t_cand, &out.best_order, t_fifo, &fifo) {
            ReorderDecision {
                order: out.best_order,
                evals,
                degraded: false,
            }
        } else {
            ReorderDecision {
                order: fifo,
                evals,
                degraded: true,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SimulatorBackend;
    use crate::workloads::scenario_by_id;

    fn sim() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
        Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
    }

    fn makespan(gpu: &GpuSpec, ks: &[KernelProfile], order: &[usize]) -> f64 {
        SimulatorBackend::new().execute(gpu, ks, order).makespan_ms
    }

    #[test]
    fn fifo_mode_is_identity() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("uniform").unwrap().workload(&gpu, 6, 1);
        let d = OnlineReorderer::fifo().decide(&gpu, &ks, sim().as_ref());
        assert_eq!(d.order, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(d.evals, 0);
        assert_eq!(OnlineReorderer::fifo().name(), "fifo");
    }

    #[test]
    fn tiny_windows_get_the_exhaustive_optimum() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("skewed").unwrap().workload(&gpu, 4, 5);
        let r = OnlineReorderer::search("local:0", 256).unwrap();
        let d = r.decide(&gpu, &ks, sim().as_ref());
        assert_eq!(d.evals, 24); // exactly 4!
        let sw = crate::perm::sweep_with(&gpu, &ks, sim().as_ref());
        assert_eq!(d.order, sw.best_order);
    }

    #[test]
    fn large_windows_use_the_anytime_strategy_and_never_lose_to_fifo() {
        let gpu = GpuSpec::gtx580();
        let r = OnlineReorderer::search("anneal:3", 300).unwrap();
        for family in ["uniform", "skewed", "small-large", "complementary", "mixed"] {
            let ks = scenario_by_id(family).unwrap().workload(&gpu, 9, 2);
            let d = r.decide(&gpu, &ks, sim().as_ref());
            let mut sorted = d.order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..9).collect::<Vec<_>>(), "{family}");
            assert!(d.evals > 0 && d.evals <= 302, "{family}: {}", d.evals);
            let fifo: Vec<usize> = (0..9).collect();
            assert!(
                makespan(&gpu, &ks, &d.order) <= makespan(&gpu, &ks, &fifo) + 1e-9,
                "{family}: search order lost to FIFO"
            );
        }
    }

    #[test]
    fn decisions_are_deterministic() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("mixed").unwrap().workload(&gpu, 10, 4);
        let r = OnlineReorderer::search("local:2", 500).unwrap();
        let a = r.decide(&gpu, &ks, sim().as_ref());
        let b = r.decide(&gpu, &ks, sim().as_ref());
        assert_eq!(a.order, b.order);
        assert_eq!(a.evals, b.evals);
    }

    #[test]
    fn bnb_and_bad_spellings_are_rejected() {
        for s in ["bnb", "exact", "branch-and-bound"] {
            let err = OnlineReorderer::search(s, 100).unwrap_err();
            assert!(err.to_string().contains("deterministic"), "{err}");
        }
        let err = OnlineReorderer::search("nope", 100).unwrap_err();
        assert!(err.to_string().contains("nope"), "{err}");
    }

    #[test]
    fn name_spells_the_config() {
        let r = OnlineReorderer::search("sa:7", 512).unwrap();
        assert_eq!(r.name(), "search:anneal:7:512");
    }

    #[test]
    fn deps_template_rejects_backward_and_oversized_edges() {
        let err = OnlineReorderer::search("local:0", 100)
            .unwrap()
            .with_deps(&[(3, 1)])
            .unwrap_err();
        assert!(err.to_string().contains("3->1"), "{err}");
        assert!(err.to_string().contains("FIFO"), "{err}");
        let err = OnlineReorderer::fifo().with_deps(&[(0, 64)]).unwrap_err();
        assert!(err.to_string().contains("0->64"), "{err}");
        assert!(err.to_string().contains("64"), "{err}");
    }

    #[test]
    fn empty_deps_template_is_bit_identical() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("mixed").unwrap().workload(&gpu, 7, 3);
        let plain = OnlineReorderer::search("anneal:5", 300).unwrap();
        let templated = plain.clone().with_deps(&[]).unwrap();
        let a = plain.decide(&gpu, &ks, sim().as_ref());
        let b = templated.decide(&gpu, &ks, sim().as_ref());
        assert_eq!(a.order, b.order);
        assert_eq!(a.evals, b.evals);
        assert_eq!(a.degraded, b.degraded);
        assert_eq!(plain.name(), templated.name());
    }

    #[test]
    fn template_edges_outside_the_window_are_ignored() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("uniform").unwrap().workload(&gpu, 4, 2);
        let plain = OnlineReorderer::search("local:1", 256).unwrap();
        let templated = plain.clone().with_deps(&[(4, 9)]).unwrap();
        let a = plain.decide(&gpu, &ks, sim().as_ref());
        let b = templated.decide(&gpu, &ks, sim().as_ref());
        assert_eq!(a.order, b.order);
        assert_eq!(a.evals, b.evals);
    }

    #[test]
    fn deps_template_exact_path_matches_constrained_sweep() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("skewed").unwrap().workload(&gpu, 5, 9);
        let edges = [(0, 2), (1, 2), (2, 4)];
        let r = OnlineReorderer::search("local:0", 256)
            .unwrap()
            .with_deps(&edges)
            .unwrap();
        let d = r.decide(&gpu, &ks, sim().as_ref());
        let graph = crate::workloads::DepGraph::build(5, &edges).unwrap();
        let sw = crate::perm::sweep_dag_with(&gpu, &ks, &graph, sim().as_ref());
        assert_eq!(d.evals, sw.n_perms as u64);
        assert_eq!(d.order, sw.best_order);
        assert!(graph.is_topological(&d.order));
        assert!(!d.degraded);
        assert!(d.evals < 120, "constrained sweep must visit fewer than 5! orders");
    }

    #[test]
    fn deps_template_anytime_is_topological_deterministic_and_guarded() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("mixed").unwrap().workload(&gpu, 9, 6);
        let edges = [(0, 3), (1, 3), (3, 7), (2, 8)];
        let graph = crate::workloads::DepGraph::build(9, &edges).unwrap();
        let r = OnlineReorderer::search("anneal:4", 200)
            .unwrap()
            .with_deps(&edges)
            .unwrap();
        let a = r.decide(&gpu, &ks, sim().as_ref());
        let b = r.decide(&gpu, &ks, sim().as_ref());
        assert_eq!(a.order, b.order, "DAG decisions must be deterministic");
        assert_eq!(a.evals, b.evals);
        assert!(graph.is_topological(&a.order));
        let fifo: Vec<usize> = (0..9).collect();
        assert!(
            makespan(&gpu, &ks, &a.order) <= makespan(&gpu, &ks, &fifo) + 1e-9,
            "guarded decision lost to FIFO"
        );
        assert_eq!(r.name(), "search:anneal:4:200+deps:4");
    }

    #[test]
    fn singleton_window_is_trivial() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("uniform").unwrap().workload(&gpu, 1, 0);
        let r = OnlineReorderer::search("local:0", 100).unwrap();
        let d = r.decide(&gpu, &ks, sim().as_ref());
        assert_eq!(d.order, vec![0]);
        assert_eq!(d.evals, 0);
    }
}
