//! Per-kernel latency accounting and run reports for the online engine.
//!
//! Every kernel's life is four timestamps — *arrival* (submission),
//! *close* (its reorder window closed), *start* (its batch began
//! service) and *finish* (the model completed it) — from which the three
//! latency components fall out:
//!
//! * **queue wait** `start − arrival`: window linger + device queueing +
//!   scheduling-decision overhead;
//! * **service** `finish − start`: time inside the executing batch;
//! * **sojourn** `finish − arrival`: what the submitter experiences, the
//!   quantity latency SLOs are written against.
//!
//! [`LatencyStats`] summarizes each component (exact p50/p95/p99 via
//! [`crate::metrics::percentile`]); [`OnlineReport::sojourn_histogram`]
//! exposes the full distribution through [`crate::metrics::Histogram`].

use crate::metrics::{mean, percentile, Histogram};
use std::fmt;

/// Why a kernel left the system unserved. One enum serves both the
/// online and fleet engines so `--record` traces round-trip
/// shed/rejected rows identically on both paths: [`fmt::Display`] is
/// the human spelling the CLI prints, [`ShedCause::to_csv`] /
/// [`ShedCause::parse_csv`] the stable machine spelling embedded in
/// recorded traces.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShedCause {
    /// Stranded on a crashed device at drain (fleet engine).
    Stranded { device: usize },
    /// Launch retry cap exhausted under a `launchfail` process.
    RetryCap { attempts: u32 },
    /// Rejected at the door by an admission policy (never entered the
    /// system; the last rung of the degradation ladder).
    Rejected { policy: String },
}

impl ShedCause {
    /// Stable machine spelling for recorded traces
    /// (`stranded:<dev>` | `retry-cap:<attempts>` | `rejected:<policy>`).
    pub fn to_csv(&self) -> String {
        match self {
            ShedCause::Stranded { device } => format!("stranded:{device}"),
            ShedCause::RetryCap { attempts } => format!("retry-cap:{attempts}"),
            ShedCause::Rejected { policy } => format!("rejected:{policy}"),
        }
    }

    /// Inverse of [`to_csv`](ShedCause::to_csv).
    pub fn parse_csv(s: &str) -> Option<ShedCause> {
        let (head, rest) = s.split_once(':')?;
        match head {
            "stranded" => Some(ShedCause::Stranded { device: rest.parse().ok()? }),
            "retry-cap" => Some(ShedCause::RetryCap { attempts: rest.parse().ok()? }),
            "rejected" => Some(ShedCause::Rejected { policy: rest.to_string() }),
            _ => None,
        }
    }
}

impl fmt::Display for ShedCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShedCause::Stranded { device } => {
                write!(f, "stranded on crashed device {device}")
            }
            ShedCause::RetryCap { attempts } => {
                write!(f, "launch failed {attempts} times (retry cap)")
            }
            ShedCause::Rejected { policy } => {
                write!(f, "rejected by admission policy `{policy}`")
            }
        }
    }
}

/// A kernel that left the system unserved — rejected by admission,
/// retry cap exhausted, or stranded on a crashed device at drain.
/// Always carries a cause: the no-kernel-lost invariant
/// (`tests/fault_recovery.rs`, `tests/overload_protection.rs`) is that
/// every arrival is a kernel record or a shed record, never neither.
#[derive(Debug, Clone, PartialEq)]
pub struct ShedRecord {
    pub id: u64,
    pub arrival_ms: f64,
    /// Launch attempts spent before shedding (1 when launch never failed
    /// — e.g. stranded on a dead device; 0 when rejected at the door).
    pub attempts: u32,
    /// Why the kernel was shed.
    pub cause: ShedCause,
}

/// Render shed records as `# shed` comment rows for `--record` traces
/// (ignored by [`crate::online::Trace::parse`], stable across both the
/// online and fleet paths). Empty string when nothing was shed.
pub fn shed_csv(shed: &[ShedRecord]) -> String {
    let mut s = String::new();
    for r in shed {
        s.push_str(&format!(
            "# shed {} {:.17e} {} {}\n",
            r.id,
            r.arrival_ms,
            r.attempts,
            r.cause.to_csv()
        ));
    }
    s
}

/// The four timestamps of one kernel's passage through the system.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelRecord {
    /// Arrival id (index into the scenario pool).
    pub id: u64,
    pub arrival_ms: f64,
    /// When this kernel's reorder window closed.
    pub close_ms: f64,
    /// When its batch began service on the device.
    pub start_ms: f64,
    /// When the model completed it.
    pub finish_ms: f64,
    /// Batch that served it, and its position in the reordered launch
    /// sequence.
    pub batch: u64,
    pub position: usize,
}

/// One dispatched reorder window.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchRecord {
    pub id: u64,
    pub n: usize,
    pub close_ms: f64,
    /// Close time plus the modeled scheduling-decision overhead.
    pub ready_ms: f64,
    pub start_ms: f64,
    pub makespan_ms: f64,
    /// Order evaluations the reorder decision spent.
    pub evals: u64,
    /// Launch order (positions into the batch).
    pub order: Vec<usize>,
}

/// Summary of one latency component (exact sample percentiles).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub n: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencyStats {
    /// Summarize a sample set (zeros for an empty one).
    pub fn from_samples(xs: &[f64]) -> LatencyStats {
        LatencyStats {
            n: xs.len(),
            mean_ms: mean(xs),
            p50_ms: percentile(xs, 50.0),
            p95_ms: percentile(xs, 95.0),
            p99_ms: percentile(xs, 99.0),
            max_ms: xs.iter().cloned().fold(0.0, f64::max),
        }
    }

    /// One-line rendering used by the CLI report.
    pub fn line(&self) -> String {
        format!(
            "mean {:>9.3} ms  p50 {:>9.3}  p95 {:>9.3}  p99 {:>9.3}  max {:>9.3}  (n={})",
            self.mean_ms, self.p50_ms, self.p95_ms, self.p99_ms, self.max_ms, self.n
        )
    }
}

/// Everything a [`crate::online::simulate_online`] run produced. All
/// quantities are in virtual milliseconds and bit-deterministic per
/// (arrival seed, strategy seed, window policy) — pinned by
/// `tests/online_determinism.rs`.
#[derive(Debug, Clone)]
pub struct OnlineReport {
    /// Spellings of the run's configuration, for display.
    pub source: String,
    pub window: String,
    pub reorderer: String,
    pub backend: String,
    /// Admission-policy spelling that gated arrivals (`"none"` when the
    /// run was ungated).
    pub admission: String,
    /// One record per kernel, sorted by arrival id.
    pub kernels: Vec<KernelRecord>,
    /// One record per dispatched window, in dispatch order.
    pub batches: Vec<BatchRecord>,
    /// Last completion time (0 for an empty run).
    pub span_ms: f64,
    /// Total device busy time (sum of batch makespans).
    pub device_busy_ms: f64,
    /// Order evaluations spent across all reorder decisions.
    pub decision_evals: u64,
    /// Batches the model backend could not time (served with zero
    /// service time; should be 0 for validated workloads).
    pub n_unsimulable: usize,
    /// Reorder decisions that fell back to FIFO arrival order after
    /// spending search budget (graceful degradation, not a failure —
    /// the served order is never worse than FIFO).
    pub n_degraded_decisions: u64,
    /// Kernels force-dropped through unsimulable batches (zero service
    /// time): the single-device shed counter, surfaced by the CLI
    /// summary so degradation is visible from `kreorder serve`.
    pub n_shed_kernels: usize,
    /// Arrivals the admission policy rejected at the door (sorted by
    /// id). Empty under `admission=none`. The extended conservation
    /// invariant is `kernels.len() + shed.len() == arrivals`.
    pub shed: Vec<ShedRecord>,
}

impl OnlineReport {
    /// Per-kernel sojourn times (`finish − arrival`), by arrival id.
    pub fn sojourns_ms(&self) -> Vec<f64> {
        self.kernels.iter().map(|k| k.finish_ms - k.arrival_ms).collect()
    }

    /// Per-kernel queue waits (`start − arrival`), by arrival id.
    pub fn queue_waits_ms(&self) -> Vec<f64> {
        self.kernels.iter().map(|k| k.start_ms - k.arrival_ms).collect()
    }

    /// Per-kernel service times (`finish − start`), by arrival id.
    pub fn services_ms(&self) -> Vec<f64> {
        self.kernels.iter().map(|k| k.finish_ms - k.start_ms).collect()
    }

    pub fn sojourn_stats(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.sojourns_ms())
    }

    pub fn queue_stats(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.queue_waits_ms())
    }

    pub fn service_stats(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.services_ms())
    }

    /// The full sojourn distribution at `n_bins` resolution.
    pub fn sojourn_histogram(&self, n_bins: usize) -> Histogram {
        Histogram::build(&self.sojourns_ms(), n_bins)
    }

    /// Sustained completion throughput over the run (kernels per virtual
    /// second).
    pub fn throughput_per_s(&self) -> f64 {
        if self.span_ms <= 0.0 {
            0.0
        } else {
            self.kernels.len() as f64 / (self.span_ms / 1e3)
        }
    }

    /// Fraction of the run the device spent executing batches.
    pub fn utilization(&self) -> f64 {
        if self.span_ms <= 0.0 {
            0.0
        } else {
            (self.device_busy_ms / self.span_ms).min(1.0)
        }
    }

    /// Mean kernels per dispatched window.
    pub fn mean_window(&self) -> f64 {
        if self.batches.is_empty() {
            0.0
        } else {
            self.kernels.len() as f64 / self.batches.len() as f64
        }
    }

    /// Fraction of arrivals that were admitted and completed (1.0 when
    /// nothing was rejected).
    pub fn completion_rate(&self) -> f64 {
        let total = self.kernels.len() + self.shed.len();
        if total > 0 {
            self.kernels.len() as f64 / total as f64
        } else {
            1.0
        }
    }

    /// Fraction of kernels whose sojourn met the SLO (1.0 for an empty
    /// run: no kernel violated it).
    pub fn slo_attainment(&self, slo_ms: f64) -> f64 {
        if self.kernels.is_empty() {
            return 1.0;
        }
        let ok = self
            .kernels
            .iter()
            .filter(|k| k.finish_ms - k.arrival_ms <= slo_ms)
            .count();
        ok as f64 / self.kernels.len() as f64
    }

    /// Multi-line human summary.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "{} kernels in {} windows (mean {:.2}/window) | span {:.2} ms | \
             {:.1} kernels/s | utilization {:.1}% | {} decision evals\n",
            self.kernels.len(),
            self.batches.len(),
            self.mean_window(),
            self.span_ms,
            self.throughput_per_s(),
            self.utilization() * 100.0,
            self.decision_evals,
        ));
        s.push_str(&format!("  sojourn : {}\n", self.sojourn_stats().line()));
        s.push_str(&format!("  queue   : {}\n", self.queue_stats().line()));
        s.push_str(&format!("  service : {}", self.service_stats().line()));
        if self.n_degraded_decisions > 0 {
            s.push_str(&format!(
                "\n  degraded: {} decisions fell back to FIFO",
                self.n_degraded_decisions
            ));
        }
        if !self.shed.is_empty() {
            s.push_str(&format!(
                "\n  admission: {} arrivals rejected ({}), completion rate {:.4}",
                self.shed.len(),
                self.admission,
                self.completion_rate(),
            ));
        }
        if self.n_unsimulable > 0 {
            s.push_str(&format!(
                "\n  WARNING: {} unsimulable batches, {} kernels shed (zero service)",
                self.n_unsimulable, self.n_shed_kernels
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(id: u64, arrival: f64, start: f64, finish: f64) -> KernelRecord {
        KernelRecord {
            id,
            arrival_ms: arrival,
            close_ms: start,
            start_ms: start,
            finish_ms: finish,
            batch: 0,
            position: id as usize,
        }
    }

    fn report(kernels: Vec<KernelRecord>) -> OnlineReport {
        let span = kernels.iter().map(|k| k.finish_ms).fold(0.0, f64::max);
        OnlineReport {
            source: "test".into(),
            window: "fixed:4".into(),
            reorderer: "fifo".into(),
            backend: "sim".into(),
            admission: "none".into(),
            batches: vec![BatchRecord {
                id: 0,
                n: kernels.len(),
                close_ms: 0.0,
                ready_ms: 0.0,
                start_ms: 0.0,
                makespan_ms: span,
                evals: 0,
                order: (0..kernels.len()).collect(),
            }],
            kernels,
            span_ms: span,
            device_busy_ms: span,
            decision_evals: 0,
            n_unsimulable: 0,
            n_degraded_decisions: 0,
            n_shed_kernels: 0,
            shed: Vec::new(),
        }
    }

    #[test]
    fn latency_components_decompose() {
        let r = report(vec![record(0, 0.0, 5.0, 15.0), record(1, 2.0, 5.0, 20.0)]);
        assert_eq!(r.queue_waits_ms(), vec![5.0, 3.0]);
        assert_eq!(r.services_ms(), vec![10.0, 15.0]);
        assert_eq!(r.sojourns_ms(), vec![15.0, 18.0]);
        // sojourn = queue + service, per kernel.
        for ((q, s), j) in r
            .queue_waits_ms()
            .iter()
            .zip(r.services_ms())
            .zip(r.sojourns_ms())
        {
            assert!((q + s - j).abs() < 1e-12);
        }
    }

    #[test]
    fn stats_percentiles_are_exact() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let st = LatencyStats::from_samples(&xs);
        assert_eq!(st.n, 100);
        assert!((st.p50_ms - 50.5).abs() < 1e-9);
        assert!((st.p99_ms - 99.01).abs() < 1e-9);
        assert_eq!(st.max_ms, 100.0);
        assert!((st.mean_ms - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_are_zero() {
        let st = LatencyStats::from_samples(&[]);
        assert_eq!(st.n, 0);
        assert_eq!(st.mean_ms, 0.0);
        assert_eq!(st.max_ms, 0.0);
    }

    #[test]
    fn throughput_and_utilization() {
        let r = report(vec![record(0, 0.0, 0.0, 100.0), record(1, 0.0, 0.0, 200.0)]);
        assert!((r.throughput_per_s() - 10.0).abs() < 1e-9); // 2 kernels / 0.2 s
        assert_eq!(r.utilization(), 1.0);
        assert_eq!(r.mean_window(), 2.0);
    }

    #[test]
    fn slo_attainment_counts_violations() {
        let r = report(vec![
            record(0, 0.0, 0.0, 10.0),
            record(1, 0.0, 0.0, 20.0),
            record(2, 0.0, 0.0, 30.0),
            record(3, 0.0, 0.0, 40.0),
        ]);
        assert_eq!(r.slo_attainment(25.0), 0.5);
        assert_eq!(r.slo_attainment(f64::INFINITY), 1.0);
        assert_eq!(r.slo_attainment(0.0), 0.0);
    }

    #[test]
    fn histogram_covers_all_kernels() {
        let r = report(vec![
            record(0, 0.0, 0.0, 10.0),
            record(1, 0.0, 0.0, 20.0),
            record(2, 0.0, 0.0, 30.0),
        ]);
        let h = r.sojourn_histogram(8);
        assert_eq!(h.total(), 3);
    }

    #[test]
    fn summary_mentions_the_load_bearing_numbers() {
        let r = report(vec![record(0, 0.0, 0.0, 10.0)]);
        let s = r.summary();
        assert!(s.contains("1 kernels in 1 windows"));
        assert!(s.contains("sojourn"));
        assert!(s.contains("queue"));
        assert!(s.contains("service"));
        assert!(!s.contains("WARNING"));
        assert!(!s.contains("degraded"));
    }

    #[test]
    fn summary_surfaces_degraded_decisions_and_shed_kernels() {
        let mut r = report(vec![record(0, 0.0, 0.0, 10.0)]);
        r.n_degraded_decisions = 3;
        r.n_unsimulable = 1;
        r.n_shed_kernels = 2;
        let s = r.summary();
        assert!(s.contains("degraded: 3 decisions fell back to FIFO"), "{s}");
        assert!(s.contains("2 kernels shed"), "{s}");
    }

    #[test]
    fn summary_surfaces_admission_rejections() {
        let mut r = report(vec![record(0, 0.0, 0.0, 10.0)]);
        assert!(!r.summary().contains("admission"), "{}", r.summary());
        r.admission = "bound:4".into();
        r.shed.push(ShedRecord {
            id: 1,
            arrival_ms: 2.0,
            attempts: 0,
            cause: ShedCause::Rejected { policy: "bound:4".into() },
        });
        let s = r.summary();
        assert!(s.contains("1 arrivals rejected (bound:4)"), "{s}");
        assert!((r.completion_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shed_cause_display_keeps_the_legacy_spellings() {
        assert_eq!(
            ShedCause::Stranded { device: 0 }.to_string(),
            "stranded on crashed device 0"
        );
        assert_eq!(
            ShedCause::RetryCap { attempts: 4 }.to_string(),
            "launch failed 4 times (retry cap)"
        );
        assert_eq!(
            ShedCause::Rejected { policy: "deadline:50".into() }.to_string(),
            "rejected by admission policy `deadline:50`"
        );
    }

    #[test]
    fn shed_cause_csv_round_trips() {
        for cause in [
            ShedCause::Stranded { device: 3 },
            ShedCause::RetryCap { attempts: 7 },
            ShedCause::Rejected { policy: "codel:5:100".into() },
        ] {
            let csv = cause.to_csv();
            assert_eq!(ShedCause::parse_csv(&csv), Some(cause.clone()), "{csv}");
        }
        assert_eq!(ShedCause::parse_csv("bogus:1"), None);
        assert_eq!(ShedCause::parse_csv("stranded"), None);
        assert_eq!(ShedCause::parse_csv("stranded:x"), None);
    }

    #[test]
    fn shed_csv_rows_are_comments_with_the_stable_cause() {
        let rows = shed_csv(&[
            ShedRecord {
                id: 4,
                arrival_ms: 1.5,
                attempts: 0,
                cause: ShedCause::Rejected { policy: "bound:8".into() },
            },
            ShedRecord {
                id: 9,
                arrival_ms: 3.0,
                attempts: 4,
                cause: ShedCause::RetryCap { attempts: 4 },
            },
        ]);
        for line in rows.lines() {
            assert!(line.starts_with("# shed "), "{line}");
        }
        assert!(rows.contains("rejected:bound:8"), "{rows}");
        assert!(rows.contains("retry-cap:4"), "{rows}");
        assert!(shed_csv(&[]).is_empty());
    }
}
