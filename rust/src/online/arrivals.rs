//! Arrival processes — timestamped kernel streams for the online engine.
//!
//! Kernels are drawn from the named [`crate::workloads::Scenario`]
//! families (the *what*), while the generators here decide the *when*:
//!
//! | spelling | process |
//! |---|---|
//! | `poisson:<rate>:<seed>` | memoryless arrivals at `rate` kernels/s |
//! | `bursty:<rate>:<seed>` | on/off-modulated Poisson: bursts at `rate`, exponential on/off phases |
//! | `closed:<clients>:<think_ms>:<seed>` | closed loop: each client resubmits `think_ms` (mean) after its previous kernel completes |
//! | `replay:<file>` | replay a recorded [`Trace`] |
//!
//! Open-loop processes (`poisson`, `bursty`) are realized as a [`Trace`]
//! — a fully materialized, seed-deterministic arrival schedule — played
//! back by [`ReplaySource`]; that makes *record → replay* the identity
//! and keeps the bit-identical-replay guarantee trivial. The closed loop
//! is genuinely reactive ([`ClosedLoopSource`] schedules its next
//! submission from [`ArrivalSource::on_completion`]) but every draw
//! comes from the same seeded [`SplitMix64`], so it is equally
//! deterministic — and its realized schedule can itself be recorded as a
//! [`Trace`] and replayed open-loop.

use crate::gpu::{GpuSpec, KernelProfile};
use crate::util::SplitMix64;
use crate::workloads::{scenario_by_id, Scenario};
use std::fmt;

/// One timestamped kernel-launch request.
#[derive(Debug, Clone)]
pub struct Arrival {
    /// Stable id: the kernel's index in the scenario pool (`pool[id]`).
    pub id: u64,
    /// Virtual arrival time.
    pub at_ms: f64,
    /// Static profile used for ordering and simulation.
    pub profile: KernelProfile,
}

/// A stream of timestamped kernel launches, consumed by
/// [`crate::online::simulate_online`]'s event loop.
pub trait ArrivalSource: Send {
    /// Human-readable spelling of this source (e.g. `"poisson:80:1"`).
    fn name(&self) -> String;

    /// Time of the next arrival, if one is currently scheduled. Open-loop
    /// sources always know; a closed-loop source returns `None` while
    /// every client is waiting on a completion.
    fn next_at(&self) -> Option<f64>;

    /// Pop the arrival previously announced by [`ArrivalSource::next_at`].
    /// Called exactly when the virtual clock reaches that time.
    fn pop(&mut self, now_ms: f64) -> Arrival;

    /// A previously popped kernel completed at `now_ms`. Open-loop
    /// sources ignore this; the closed loop schedules its client's next
    /// submission from it.
    fn on_completion(&mut self, now_ms: f64, id: u64) {
        let _ = (now_ms, id);
    }
}

// ---------------------------------------------------------------------------
// Trace: a materialized, replayable arrival schedule
// ---------------------------------------------------------------------------

/// A recorded arrival schedule: the scenario-pool coordinates plus one
/// arrival time per kernel (kernel `i` of
/// `scenario_by_id(family).workload(gpu, n, seed)` arrives at
/// `times_ms[i]`). Serializes to a small CSV so a production incident
/// (or an interesting synthetic run) can be replayed bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub family: String,
    pub n: usize,
    pub seed: u64,
    /// Device count of the fleet the trace was recorded for (1 for
    /// single-device runs — the `v1` CSV format, which omits the
    /// field). Replaying onto a smaller fleet is rejected by
    /// [`crate::fleet::FleetSpec::validate_trace`]: the recorded
    /// overload regime would silently change.
    pub devices: usize,
    /// Non-decreasing arrival times, one per kernel.
    pub times_ms: Vec<f64>,
}

/// Mean kernels per ON burst of the `bursty` process (documented
/// contract of the `bursty:<rate>:<seed>` spelling).
const BURST_MEAN_KERNELS: f64 = 16.0;

impl Trace {
    /// Poisson arrivals: exponential inter-arrival times at
    /// `rate_per_s` kernels per (virtual) second.
    pub fn poisson(family: &str, n: usize, rate_per_s: f64, seed: u64) -> Trace {
        assert!(rate_per_s > 0.0, "arrival rate must be positive");
        let mut rng = SplitMix64::new(seed ^ 0xA221_7001);
        let mean_gap_ms = 1e3 / rate_per_s;
        let mut t = 0.0f64;
        let times_ms = (0..n)
            .map(|_| {
                t += exp_draw(&mut rng, mean_gap_ms);
                t
            })
            .collect();
        Trace {
            family: family.to_string(),
            n,
            seed,
            devices: 1,
            times_ms,
        }
    }

    /// On/off-modulated Poisson: during ON phases kernels arrive at
    /// `rate_per_s`; phases alternate with exponential durations sized so
    /// a burst carries ~16 kernels on average (`BURST_MEAN_KERNELS`) and
    /// the duty cycle is ~50% (effective rate ≈ `rate_per_s / 2`). The
    /// clustered arrivals stress the window policies far harder than the
    /// memoryless stream at the same mean rate.
    pub fn bursty(family: &str, n: usize, rate_per_s: f64, seed: u64) -> Trace {
        assert!(rate_per_s > 0.0, "arrival rate must be positive");
        let mut rng = SplitMix64::new(seed ^ 0xA221_7002);
        let mean_gap_ms = 1e3 / rate_per_s;
        let mean_phase_ms = BURST_MEAN_KERNELS * mean_gap_ms;
        let mut t = 0.0f64;
        let mut phase_ends = exp_draw(&mut rng, mean_phase_ms);
        let mut times_ms = Vec::with_capacity(n);
        while times_ms.len() < n {
            let gap = exp_draw(&mut rng, mean_gap_ms);
            if t + gap <= phase_ends {
                t += gap;
                times_ms.push(t);
            } else {
                // Burst over: skip the OFF phase, start the next burst.
                t = phase_ends + exp_draw(&mut rng, mean_phase_ms);
                phase_ends = t + exp_draw(&mut rng, mean_phase_ms);
            }
        }
        Trace {
            family: family.to_string(),
            n,
            seed,
            devices: 1,
            times_ms,
        }
    }

    /// Stamp the fleet device count the trace is recorded for (clamped
    /// to at least 1). Single-device traces serialize without the
    /// `devices=` field, staying byte-identical to the original `v1`
    /// format.
    pub fn with_devices(mut self, devices: usize) -> Trace {
        self.devices = devices.max(1);
        self
    }

    /// The scenario pool this trace draws kernels from (`pool[i]` is the
    /// kernel arriving at `times_ms[i]`).
    pub fn pool(&self, gpu: &GpuSpec) -> Option<Vec<KernelProfile>> {
        Some(scenario_by_id(&self.family)?.workload(gpu, self.n, self.seed))
    }

    /// Serialize as a small replayable CSV (`# kreorder-trace` header
    /// carrying the pool coordinates, one `at_ms` row per kernel).
    pub fn to_csv(&self) -> String {
        let devices = if self.devices > 1 {
            format!(" devices={}", self.devices)
        } else {
            String::new()
        };
        let mut s = format!(
            "# kreorder-trace v1 family={} n={} seed={}{devices}\nat_ms\n",
            self.family, self.n, self.seed
        );
        for t in &self.times_ms {
            // 17 significant digits round-trip f64 exactly.
            s.push_str(&format!("{t:.17e}\n"));
        }
        s
    }

    /// Parse the [`Trace::to_csv`] format.
    pub fn parse(text: &str) -> Result<Trace, TraceParseError> {
        let err = |m: &str| TraceParseError { message: m.into() };
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| err("empty trace"))?;
        if !header.starts_with("# kreorder-trace v1 ") {
            return Err(err("missing `# kreorder-trace v1` header"));
        }
        let (mut family, mut n, mut seed) = (None, None, None);
        // Absent devices= means the single-device v1 format.
        let mut devices = 1usize;
        for field in header.split_whitespace().skip(3) {
            match field.split_once('=') {
                Some(("family", v)) => family = Some(v.to_string()),
                Some(("n", v)) => n = v.parse::<usize>().ok(),
                Some(("seed", v)) => seed = v.parse::<u64>().ok(),
                Some(("devices", v)) => match v.parse::<usize>() {
                    Ok(d) if d >= 1 => devices = d,
                    _ => return Err(err(&format!("invalid header field `{field}`"))),
                },
                _ => return Err(err(&format!("unknown header field `{field}`"))),
            }
        }
        let family = family.ok_or_else(|| err("header missing family="))?;
        let n = n.ok_or_else(|| err("header missing or invalid n="))?;
        let seed = seed.ok_or_else(|| err("header missing or invalid seed="))?;
        match lines.next() {
            Some("at_ms") => {}
            _ => return Err(err("missing `at_ms` column header")),
        }
        let mut times_ms = Vec::with_capacity(n);
        // The engine's clock starts at 0 and only moves forward, so a
        // trace must be non-negative as well as non-decreasing.
        let mut prev = 0.0f64;
        for line in lines {
            let line = line.trim();
            // Tolerate `#`-prefixed annotation rows after the header —
            // `--record` appends `# shed …` rows (see
            // [`crate::online::shed_csv`]) so a recorded overload run
            // still replays through the arrival rows alone.
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let t: f64 = line
                .parse()
                .map_err(|_| err(&format!("bad arrival time `{line}`")))?;
            if !t.is_finite() || t < prev {
                return Err(err(
                    "arrival times must be finite, non-negative and non-decreasing",
                ));
            }
            prev = t;
            times_ms.push(t);
        }
        if times_ms.len() != n {
            return Err(err(&format!(
                "header says n={n} but {} arrival rows present",
                times_ms.len()
            )));
        }
        Ok(Trace {
            family,
            n,
            seed,
            devices,
            times_ms,
        })
    }
}

/// Exponential draw with the given mean (inverse-CDF; strictly positive).
fn exp_draw(rng: &mut SplitMix64, mean: f64) -> f64 {
    // 1 - next_f64() is in (0, 1]; ln of it is finite and <= 0.
    -(1.0 - rng.next_f64()).ln() * mean
}

/// Error parsing a recorded trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceParseError {
    pub message: String,
}

impl fmt::Display for TraceParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid kreorder trace: {}", self.message)
    }
}

impl std::error::Error for TraceParseError {}

// ---------------------------------------------------------------------------
// Sources
// ---------------------------------------------------------------------------

/// Open-loop playback of a [`Trace`] (also the realization of the
/// `poisson` / `bursty` spellings).
pub struct ReplaySource {
    name: String,
    arrivals: Vec<Arrival>,
    next: usize,
}

impl ReplaySource {
    /// Build from a trace. Fails when the trace names an unknown
    /// scenario family.
    pub fn from_trace(trace: &Trace, gpu: &GpuSpec) -> Result<Self, TraceParseError> {
        let pool = trace.pool(gpu).ok_or_else(|| TraceParseError {
            message: format!("unknown scenario family `{}`", trace.family),
        })?;
        let arrivals = trace
            .times_ms
            .iter()
            .zip(pool)
            .enumerate()
            .map(|(i, (&at_ms, profile))| Arrival {
                id: i as u64,
                at_ms,
                profile,
            })
            .collect();
        Ok(ReplaySource {
            name: format!("replay:{}:{}:{}", trace.family, trace.n, trace.seed),
            arrivals,
            next: 0,
        })
    }

    /// Override the reported spelling (so `poisson:…` runs report their
    /// generator, not `replay:…`).
    pub fn named(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }
}

impl ArrivalSource for ReplaySource {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn next_at(&self) -> Option<f64> {
        self.arrivals.get(self.next).map(|a| a.at_ms)
    }

    fn pop(&mut self, _now_ms: f64) -> Arrival {
        let a = self.arrivals[self.next].clone();
        self.next += 1;
        a
    }
}

/// Closed-loop source: `clients` concurrent submitters, each issuing its
/// next kernel an exponential think time (mean `think_ms`) after its
/// previous one **completes**. Arrival pressure is therefore coupled to
/// service speed — the regime where reordering's makespan wins feed
/// straight back into the offered load.
pub struct ClosedLoopSource {
    clients: usize,
    think_ms: f64,
    seed: u64,
    pool: Vec<KernelProfile>,
    /// Submission times already scheduled but not yet popped (min-heap
    /// by time via sorted Vec — client count is small).
    scheduled: Vec<f64>,
    issued: usize,
    rng: SplitMix64,
}

impl ClosedLoopSource {
    /// `n` bounds the total number of submissions (the run's length).
    pub fn new(
        family: &Scenario,
        gpu: &GpuSpec,
        n: usize,
        clients: usize,
        think_ms: f64,
        seed: u64,
    ) -> Self {
        let clients = clients.max(1);
        let mut rng = SplitMix64::new(seed ^ 0xA221_7003);
        // Initial submissions staggered by one think time each, so
        // clients don't all collide at t=0.
        let mut scheduled: Vec<f64> = (0..clients.min(n))
            .map(|_| exp_draw(&mut rng, think_ms.max(0.0).max(1e-6)))
            .collect();
        scheduled.sort_by(f64::total_cmp);
        ClosedLoopSource {
            clients,
            think_ms: think_ms.max(0.0),
            seed,
            pool: family.workload(gpu, n, seed),
            scheduled,
            issued: 0,
            rng,
        }
    }
}

impl ArrivalSource for ClosedLoopSource {
    fn name(&self) -> String {
        format!("closed:{}:{}:{}", self.clients, self.think_ms, self.seed)
    }

    fn next_at(&self) -> Option<f64> {
        if self.issued >= self.pool.len() {
            return None;
        }
        self.scheduled.first().copied()
    }

    fn pop(&mut self, _now_ms: f64) -> Arrival {
        let at_ms = self.scheduled.remove(0);
        let id = self.issued as u64;
        let profile = self.pool[self.issued].clone();
        self.issued += 1;
        Arrival { id, at_ms, profile }
    }

    fn on_completion(&mut self, now_ms: f64, _id: u64) {
        // The completing client thinks, then submits — unless the run's
        // submission budget is already fully scheduled.
        if self.issued + self.scheduled.len() >= self.pool.len() {
            return;
        }
        let t = now_ms + exp_draw(&mut self.rng, self.think_ms.max(1e-6));
        let at = self
            .scheduled
            .iter()
            .position(|&x| x > t)
            .unwrap_or(self.scheduled.len());
        self.scheduled.insert(at, t);
    }
}

// ---------------------------------------------------------------------------
// Spelling registry
// ---------------------------------------------------------------------------

/// A parsed `--arrivals` spelling.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    Poisson { rate_per_s: f64, seed: u64 },
    Bursty { rate_per_s: f64, seed: u64 },
    Closed { clients: usize, think_ms: f64, seed: u64 },
    /// Replay a recorded trace file; the caller loads the file (this
    /// module does no I/O).
    Replay { path: String },
}

/// Error for unknown arrival spellings; `Display` lists the valid forms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrivalParseError {
    pub input: String,
}

impl fmt::Display for ArrivalParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown arrival process `{}` — valid processes: poisson:<rate>:<seed>, \
             bursty:<rate>:<seed>, closed:<clients>:<think_ms>:<seed>, replay:<file>",
            self.input
        )
    }
}

impl std::error::Error for ArrivalParseError {}

impl ArrivalSpec {
    /// Parse an arrival-process spelling.
    ///
    /// ```
    /// use kreorder::online::ArrivalSpec;
    /// assert!(matches!(
    ///     ArrivalSpec::parse("poisson:80:1"),
    ///     Ok(ArrivalSpec::Poisson { .. })
    /// ));
    /// assert!(ArrivalSpec::parse("uniform:3").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<ArrivalSpec, ArrivalParseError> {
        let err = || ArrivalParseError { input: s.into() };
        let (head, rest) = s.split_once(':').ok_or_else(err)?;
        let rate = |p: &str| -> Result<f64, ArrivalParseError> {
            let v: f64 = p.parse().map_err(|_| err())?;
            if v.is_finite() && v > 0.0 {
                Ok(v)
            } else {
                Err(err())
            }
        };
        match head.to_ascii_lowercase().as_str() {
            "poisson" => {
                let (r, seed) = rest.split_once(':').ok_or_else(err)?;
                Ok(ArrivalSpec::Poisson {
                    rate_per_s: rate(r)?,
                    seed: seed.parse().map_err(|_| err())?,
                })
            }
            "bursty" => {
                let (r, seed) = rest.split_once(':').ok_or_else(err)?;
                Ok(ArrivalSpec::Bursty {
                    rate_per_s: rate(r)?,
                    seed: seed.parse().map_err(|_| err())?,
                })
            }
            "closed" => {
                let mut parts = rest.split(':');
                let clients: usize = parts.next().ok_or_else(err)?.parse().map_err(|_| err())?;
                let think: f64 = parts.next().ok_or_else(err)?.parse().map_err(|_| err())?;
                let seed: u64 = parts.next().ok_or_else(err)?.parse().map_err(|_| err())?;
                if parts.next().is_some() || clients == 0 || !think.is_finite() || think < 0.0 {
                    return Err(err());
                }
                Ok(ArrivalSpec::Closed {
                    clients,
                    think_ms: think,
                    seed,
                })
            }
            "replay" => Ok(ArrivalSpec::Replay { path: rest.into() }),
            _ => Err(err()),
        }
    }

    /// Materialize the open-loop spellings as a [`Trace`] over `family`
    /// (`None` for `closed` / `replay`, which are not trace-shaped up
    /// front).
    pub fn trace(&self, family: &str, n: usize) -> Option<Trace> {
        match self {
            ArrivalSpec::Poisson { rate_per_s, seed } => {
                Some(Trace::poisson(family, n, *rate_per_s, *seed))
            }
            ArrivalSpec::Bursty { rate_per_s, seed } => {
                Some(Trace::bursty(family, n, *rate_per_s, *seed))
            }
            _ => None,
        }
    }

    /// The spelling's canonical display form.
    pub fn name(&self) -> String {
        match self {
            ArrivalSpec::Poisson { rate_per_s, seed } => format!("poisson:{rate_per_s}:{seed}"),
            ArrivalSpec::Bursty { rate_per_s, seed } => format!("bursty:{rate_per_s}:{seed}"),
            ArrivalSpec::Closed {
                clients,
                think_ms,
                seed,
            } => format!("closed:{clients}:{think_ms}:{seed}"),
            ArrivalSpec::Replay { path } => format!("replay:{path}"),
        }
    }
}

/// Human-readable table of the arrival spellings (one per line).
pub fn arrival_help_table() -> String {
    let rows = [
        ("poisson:<rate>:<seed>", "memoryless arrivals at <rate> kernels per virtual second"),
        (
            "bursty:<rate>:<seed>",
            "on/off bursts at <rate> during ON phases (~16 kernels/burst, ~50% duty)",
        ),
        (
            "closed:<c>:<think>:<seed>",
            "closed loop: <c> clients, each resubmitting <think> ms (mean) after completion",
        ),
        ("replay:<file>", "replay a trace recorded with `kreorder serve --record`"),
    ];
    let mut out = String::new();
    for (name, desc) in rows {
        out.push_str(&format!("  {name:<26} {desc}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> GpuSpec {
        GpuSpec::gtx580()
    }

    #[test]
    fn poisson_is_deterministic_and_ordered() {
        let a = Trace::poisson("uniform", 50, 100.0, 7);
        let b = Trace::poisson("uniform", 50, 100.0, 7);
        assert_eq!(a, b);
        assert_ne!(a, Trace::poisson("uniform", 50, 100.0, 8));
        assert_eq!(a.times_ms.len(), 50);
        for w in a.times_ms.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!(a.times_ms.iter().all(|t| t.is_finite() && *t > 0.0));
        // Mean inter-arrival should land near 10 ms at 100/s.
        let mean_gap = a.times_ms.last().unwrap() / 50.0;
        assert!((2.0..50.0).contains(&mean_gap), "mean gap {mean_gap}");
    }

    #[test]
    fn bursty_clusters_more_than_poisson() {
        let p = Trace::poisson("uniform", 200, 100.0, 3);
        let b = Trace::bursty("uniform", 200, 100.0, 3);
        // Same ON rate, ~50% duty: the bursty trace takes longer overall…
        assert!(b.times_ms.last().unwrap() > p.times_ms.last().unwrap());
        // …yet its shortest gaps match the ON-phase rate (clustering).
        let min_gap = |t: &Trace| {
            t.times_ms
                .windows(2)
                .map(|w| w[1] - w[0])
                .fold(f64::INFINITY, f64::min)
        };
        assert!(min_gap(&b) < 10.0, "no intra-burst clustering");
        for w in b.times_ms.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn trace_csv_round_trips_bit_exactly() {
        let t = Trace::bursty("skewed", 31, 42.5, 9);
        let parsed = Trace::parse(&t.to_csv()).unwrap();
        assert_eq!(parsed.family, t.family);
        assert_eq!(parsed.n, t.n);
        assert_eq!(parsed.seed, t.seed);
        assert_eq!(parsed.times_ms.len(), t.times_ms.len());
        for (a, b) in parsed.times_ms.iter().zip(&t.times_ms) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn trace_parse_skips_comment_rows_after_the_header() {
        // `--record` appends `# shed …` annotation rows; replay must
        // read the arrival rows straight past them.
        let t = Trace::poisson("uniform", 3, 200.0, 5);
        let mut csv = t.to_csv();
        csv.push_str("# shed 7 1.00000000000000000e2 0 rejected:bound:4\n");
        let parsed = Trace::parse(&csv).unwrap();
        assert_eq!(parsed.times_ms.len(), 3);
        for (a, b) in parsed.times_ms.iter().zip(&t.times_ms) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn trace_parse_rejects_garbage() {
        for bad in [
            "",
            "at_ms\n1.0\n",
            "# kreorder-trace v1 family=uniform n=2 seed=0\nat_ms\n1.0\n",
            "# kreorder-trace v1 family=uniform n=1 seed=0\nat_ms\nNaN\n",
            "# kreorder-trace v1 family=uniform n=2 seed=0\nat_ms\n2.0\n1.0\n",
            "# kreorder-trace v1 family=uniform n=1 seed=0\nat_ms\n-5.0\n",
            "# kreorder-trace v1 n=1 seed=0\nat_ms\n1.0\n",
            "# kreorder-trace v1 family=uniform n=1 seed=0 bogus=1\nat_ms\n1.0\n",
            "# kreorder-trace v1 family=uniform n=1 seed=0 devices=0\nat_ms\n1.0\n",
            "# kreorder-trace v1 family=uniform n=1 seed=0 devices=x\nat_ms\n1.0\n",
        ] {
            assert!(Trace::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn trace_devices_field_round_trips_and_defaults_to_one() {
        // Without the field (the v1 format) a trace is single-device…
        let t = Trace::poisson("uniform", 3, 100.0, 2);
        assert_eq!(t.devices, 1);
        assert!(!t.to_csv().contains("devices="), "{}", t.to_csv());
        assert_eq!(Trace::parse(&t.to_csv()).unwrap().devices, 1);
        // …and a fleet-stamped trace carries its device count through
        // the CSV bit-exactly.
        let f = t.clone().with_devices(4);
        let csv = f.to_csv();
        assert!(csv.contains("devices=4"), "{csv}");
        let parsed = Trace::parse(&csv).unwrap();
        assert_eq!(parsed, f);
        // with_devices clamps to at least one device.
        assert_eq!(Trace::poisson("uniform", 1, 1.0, 0).with_devices(0).devices, 1);
    }

    #[test]
    fn replay_source_plays_pool_in_order() {
        let t = Trace::poisson("skewed", 12, 50.0, 4);
        let pool = t.pool(&gpu()).unwrap();
        let mut src = ReplaySource::from_trace(&t, &gpu()).unwrap();
        for i in 0..12u64 {
            let at = src.next_at().unwrap();
            let a = src.pop(at);
            assert_eq!(a.id, i);
            assert_eq!(a.at_ms.to_bits(), t.times_ms[i as usize].to_bits());
            assert_eq!(a.profile, pool[i as usize]);
        }
        assert!(src.next_at().is_none());
    }

    #[test]
    fn replay_unknown_family_errors() {
        let t = Trace {
            family: "no-such-family".into(),
            n: 1,
            seed: 0,
            devices: 1,
            times_ms: vec![1.0],
        };
        assert!(ReplaySource::from_trace(&t, &gpu()).is_err());
    }

    #[test]
    fn closed_loop_bounds_outstanding_and_total() {
        let fam = scenario_by_id("uniform").unwrap();
        let mut src = ClosedLoopSource::new(fam, &gpu(), 10, 3, 5.0, 1);
        // At most `clients` submissions are ever scheduled before
        // completions come back.
        let mut popped = Vec::new();
        while popped.len() < 3 {
            let at = src.next_at().unwrap();
            popped.push(src.pop(at));
        }
        assert!(src.next_at().is_none(), "4th submission before any completion");
        // Completions release one new submission each, up to the total.
        for k in 0..7u64 {
            src.on_completion(100.0 + k as f64, k % 3);
            let at = src.next_at().unwrap();
            popped.push(src.pop(at));
        }
        assert!(src.next_at().is_none());
        src.on_completion(500.0, 9); // budget exhausted: no 11th kernel
        assert!(src.next_at().is_none());
        assert_eq!(popped.len(), 10);
        let ids: Vec<u64> = popped.iter().map(|a| a.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn closed_loop_is_deterministic() {
        let fam = scenario_by_id("mixed").unwrap();
        let run = |seed| {
            let mut src = ClosedLoopSource::new(fam, &gpu(), 6, 2, 3.0, seed);
            let mut times = Vec::new();
            for i in 0..6 {
                let at = src.next_at().unwrap();
                let a = src.pop(at);
                times.push(a.at_ms);
                src.on_completion(a.at_ms + 10.0, i);
            }
            times
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn spellings_parse() {
        assert_eq!(
            ArrivalSpec::parse("poisson:80:1").unwrap(),
            ArrivalSpec::Poisson {
                rate_per_s: 80.0,
                seed: 1
            }
        );
        assert_eq!(
            ArrivalSpec::parse("BURSTY:12.5:3").unwrap(),
            ArrivalSpec::Bursty {
                rate_per_s: 12.5,
                seed: 3
            }
        );
        assert_eq!(
            ArrivalSpec::parse("closed:4:25:9").unwrap(),
            ArrivalSpec::Closed {
                clients: 4,
                think_ms: 25.0,
                seed: 9
            }
        );
        assert_eq!(
            ArrivalSpec::parse("replay:/tmp/trace.csv").unwrap(),
            ArrivalSpec::Replay {
                path: "/tmp/trace.csv".into()
            }
        );
        for bad in [
            "poisson",
            "poisson:80",
            "poisson:-1:0",
            "poisson:x:0",
            "closed:0:5:1",
            "closed:2:5:1:9",
            "nonsense:1:2",
        ] {
            let err = ArrivalSpec::parse(bad).unwrap_err();
            assert!(err.to_string().contains(bad), "{err}");
        }
    }

    #[test]
    fn spec_trace_only_for_open_loop() {
        assert!(ArrivalSpec::parse("poisson:10:0").unwrap().trace("uniform", 5).is_some());
        assert!(ArrivalSpec::parse("bursty:10:0").unwrap().trace("uniform", 5).is_some());
        assert!(ArrivalSpec::parse("closed:2:5:0").unwrap().trace("uniform", 5).is_none());
        assert!(ArrivalSpec::parse("replay:x").unwrap().trace("uniform", 5).is_none());
    }

    #[test]
    fn help_table_covers_spellings() {
        let t = arrival_help_table();
        for name in ["poisson:", "bursty:", "closed:", "replay:"] {
            assert!(t.contains(name));
        }
    }
}
