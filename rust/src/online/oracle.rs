//! The clairvoyant offline baseline — what the whole trace would cost if
//! every kernel were known at `t = 0` and launched as one optimally
//! ordered batch.
//!
//! The gap between a run's online completion span and this makespan is
//! the **price of onlineness**: arrival-imposed idleness, window
//! fragmentation (each window is ordered in isolation), queueing, and
//! whatever optimality the budgeted per-window search gave up. The
//! online bench reports it per arrival regime.

use crate::exec::ExecutionBackend;
use crate::gpu::{GpuSpec, KernelProfile};
use crate::search::{
    BackendFactory, BranchAndBound, improves, LocalSearch, SearchBudget, SearchStrategy,
    SimulatedAnnealing,
};

/// Largest trace the oracle solves exactly (branch-and-bound to
/// completion); beyond it the bound is the best of two seeded anytime
/// strategies, so it is an *upper* bound on the true offline optimum —
/// the reported online gap is then a lower bound on the real price.
pub const ORACLE_EXACT_MAX_N: usize = 10;

/// What the offline oracle found for one full trace.
#[derive(Debug, Clone)]
pub struct OracleOutcome {
    /// Makespan of the whole trace under the oracle's order.
    pub makespan_ms: f64,
    /// `"bnb-exact"` (provable optimum) or `"anytime"` (upper bound).
    pub method: String,
    /// Order evaluations the oracle spent.
    pub evals: u64,
}

/// Solve the full-trace ordering problem offline: exact branch-and-bound
/// up to [`ORACLE_EXACT_MAX_N`] kernels, otherwise the best of seeded
/// annealing and local search at `anytime_evals` total evaluations
/// (split between them). Deterministic either way.
pub fn offline_oracle(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    make_backend: &BackendFactory,
    anytime_evals: u64,
) -> OracleOutcome {
    let n = kernels.len();
    if n == 0 {
        return OracleOutcome {
            makespan_ms: 0.0,
            method: "empty".into(),
            evals: 0,
        };
    }
    if n <= ORACLE_EXACT_MAX_N {
        let out =
            BranchAndBound::new().search(gpu, kernels, make_backend, &SearchBudget::unlimited());
        return OracleOutcome {
            makespan_ms: out.best_ms,
            method: "bnb-exact".into(),
            evals: out.evals,
        };
    }
    let budget = SearchBudget::evals((anytime_evals / 2).max(1));
    let strategies: [Box<dyn SearchStrategy>; 2] = [
        Box::new(SimulatedAnnealing::new(0)),
        Box::new(LocalSearch::new(1)),
    ];
    let mut best_ms = f64::INFINITY;
    let mut best_order: Vec<usize> = Vec::new();
    let mut evals = 0;
    for s in strategies {
        let out = s.search(gpu, kernels, make_backend, &budget);
        evals += out.evals;
        if improves(out.best_ms, &out.best_order, best_ms, &best_order) {
            best_ms = out.best_ms;
            best_order = out.best_order;
        }
    }
    OracleOutcome {
        makespan_ms: best_ms,
        method: "anytime".into(),
        evals,
    }
}

/// FIFO service capacity of a kernel pool (kernels per virtual second)
/// when executed as back-to-back windows of `window_cap` kernels in
/// arrival order — the load normalization the online bench and its
/// regression tests share to calibrate arrival rates against a family's
/// actual service speed. Unsimulable chunks contribute zero service
/// time; an empty pool has zero capacity.
pub fn fifo_window_capacity_per_s(
    gpu: &GpuSpec,
    kernels: &[KernelProfile],
    window_cap: usize,
    make_backend: &BackendFactory,
) -> f64 {
    if kernels.is_empty() {
        return 0.0;
    }
    let mut backend = make_backend();
    let mut total_ms = 0.0;
    for chunk in kernels.chunks(window_cap.max(1)) {
        let order: Vec<usize> = (0..chunk.len()).collect();
        let m = backend.execute(gpu, chunk, &order).makespan_ms;
        if m.is_finite() {
            total_ms += m;
        }
    }
    if total_ms <= 0.0 {
        0.0
    } else {
        kernels.len() as f64 / (total_ms / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::SimulatorBackend;
    use crate::perm::sweep_with;
    use crate::workloads::scenario_by_id;

    fn sim() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
        Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
    }

    #[test]
    fn exact_oracle_matches_the_sweep_optimum() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("skewed").unwrap().workload(&gpu, 6, 3);
        let f = sim();
        let oracle = offline_oracle(&gpu, &ks, f.as_ref(), 1000);
        assert_eq!(oracle.method, "bnb-exact");
        let sweep = sweep_with(&gpu, &ks, f.as_ref());
        assert_eq!(oracle.makespan_ms.to_bits(), sweep.best_ms.to_bits());
    }

    #[test]
    fn anytime_oracle_is_deterministic_and_no_worse_than_greedy() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("uniform").unwrap().workload(&gpu, 14, 5);
        let f = sim();
        let a = offline_oracle(&gpu, &ks, f.as_ref(), 2000);
        let b = offline_oracle(&gpu, &ks, f.as_ref(), 2000);
        assert_eq!(a.method, "anytime");
        assert_eq!(a.makespan_ms.to_bits(), b.makespan_ms.to_bits());
        assert_eq!(a.evals, b.evals);
        // Both strategies warm-start from Algorithm 1, so the oracle can
        // never be worse than the greedy order.
        let greedy = crate::sched::reorder(&gpu, &ks).order;
        let t_greedy = SimulatorBackend::new().execute(&gpu, &ks, &greedy).makespan_ms;
        assert!(a.makespan_ms <= t_greedy + 1e-9);
    }

    #[test]
    fn capacity_is_positive_and_window_sensitive() {
        let gpu = GpuSpec::gtx580();
        let ks = scenario_by_id("uniform").unwrap().workload(&gpu, 16, 1);
        let f = sim();
        let c8 = fifo_window_capacity_per_s(&gpu, &ks, 8, f.as_ref());
        assert!(c8 > 0.0);
        // Same pool, same chunking, same backend: deterministic.
        assert_eq!(
            c8.to_bits(),
            fifo_window_capacity_per_s(&gpu, &ks, 8, f.as_ref()).to_bits()
        );
        // Window size changes the measured regime (different chunking,
        // different concurrency): the helper must respect it.
        let c1 = fifo_window_capacity_per_s(&gpu, &ks, 1, f.as_ref());
        assert!(c1 > 0.0);
        assert_ne!(c1.to_bits(), c8.to_bits());
        assert_eq!(fifo_window_capacity_per_s(&gpu, &[], 8, f.as_ref()), 0.0);
    }

    #[test]
    fn empty_trace_is_trivial() {
        let gpu = GpuSpec::gtx580();
        let f = sim();
        let o = offline_oracle(&gpu, &[], f.as_ref(), 100);
        assert_eq!(o.makespan_ms, 0.0);
        assert_eq!(o.evals, 0);
    }
}
