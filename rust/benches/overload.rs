//! Bench + CI gate: **overload protection** — admission control and the
//! graceful-degradation ladder under sustained overload, on the virtual
//! clock.
//!
//! For each gated scenario family the bench:
//!
//! 1. calibrates the 2-device fleet's summed FIFO window capacity (the
//!    `benches/fleet_routing.rs` normalization) and measures the
//!    critical-load (`1.0x`, ungated) p99 sojourn — the deadline SLO is
//!    derived from it (`max(2 * p99_critical, 60 ms)`), so the gate
//!    self-calibrates instead of hard-coding a latency;
//! 2. drives Poisson arrivals at **1.5x and 3x** capacity — past what
//!    any reordering can absorb — and replays the **identical** trace
//!    through four admission policies: `none` (the pathology row),
//!    `bound:32` (hard occupancy cap), `deadline:<slo>` (shed on
//!    predicted-SLO-violation, priced by the backend's admissible
//!    suffix bound) and `codel:<target>:<interval>` (informational);
//! 3. scores each run by **admitted p99** (completed sojourns), goodput
//!    (completed kernels per second of span) and the conservation
//!    ledger (`completed + shed == arrivals`; under admission, shed =
//!    rejected since no faults run here).
//!
//! **Hard gates** (non-zero exit, CI runs `--quick` per push):
//!
//! * conservation — every run, every policy: nothing lost, nothing
//!   double-counted; `none` sheds exactly zero;
//! * the SLO holds under shed — `deadline:<slo>`'s admitted p99 stays
//!   ≤ the SLO at both overloads **while** goodput stays ≥ half the
//!   fleet's calibrated capacity (no passing the latency gate by
//!   shedding everything);
//! * the pathology is real — at 3x, ungated `none`'s p99 must exceed
//!   `bound:32`'s admitted p99 (unbounded queue growth vs a bounded
//!   queue), otherwise the overload regime is miscalibrated.
//!
//! Everything is virtual-time: `BENCH_overload.json` is machine-
//! independent, so regressions are scheduling changes, never noise.

#[path = "harness/mod.rs"]
#[allow(dead_code)]
mod harness;

use kreorder::fleet::{FleetReport, FleetSimConfig, FleetSpec, ShedCause};
use kreorder::gpu::GpuSpec;
use kreorder::online::{
    fifo_window_capacity_per_s, OnlineReorderer, ReplaySource, Trace,
};
use kreorder::workloads::scenario_by_id;

const SEED: u64 = 31;
const WINDOW_CAP: usize = 8;
const WINDOW_SPEC: &str = "linger:8:40";
const SEARCH_BUDGET: u64 = 300;
/// Two identical devices (overload is about load, not heterogeneity).
const FLEET: &str = "2";
/// Offered load relative to summed FIFO capacity, per regime.
const OVERLOADS: [f64; 2] = [1.5, 3.0];
/// Hard occupancy cap for the `bound` rows (~4 windows across 2 devices).
const BOUND_Q: usize = 32;
/// Goodput floor for the deadline gate, as a fraction of capacity.
const GOODPUT_FLOOR_FRAC: f64 = 0.5;
/// Families the SLO and pathology gates are enforced on.
const GATED_FAMILIES: [&str; 2] = ["skewed", "mixed"];

struct Row {
    family: &'static str,
    overload: f64,
    admission: String,
    arrivals: String,
    n: usize,
    completed: usize,
    rejected: usize,
    admitted_p99_ms: f64,
    goodput_per_s: f64,
    completion_rate: f64,
    degraded_decisions: u64,
    span_ms: f64,
}

fn run_trace(fleet: &FleetSpec, trace: &Trace, admission: &str) -> FleetReport {
    let gpu = GpuSpec::gtx580();
    let source = Box::new(
        ReplaySource::from_trace(trace, &gpu)
            .expect("registry family")
            .named(trace.family.clone()),
    );
    FleetSimConfig::new(fleet.clone(), source)
        .route_named("jsq")
        .expect("bench route spelling")
        .window_named(WINDOW_SPEC)
        .expect("bench window spelling")
        .reorderer(OnlineReorderer::search("local:0", SEARCH_BUDGET).expect("spelling"))
        .admission_named(admission)
        .expect("bench admission spelling")
        .run()
}

/// Completed kernels per second of span (0 when the span is empty).
fn goodput(r: &FleetReport) -> f64 {
    if r.span_ms <= 0.0 {
        0.0
    } else {
        r.kernels.len() as f64 / (r.span_ms / 1e3)
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let gpu = GpuSpec::gtx580();
    let count: usize = if quick { 96 } else { 160 };
    let fleet = FleetSpec::parse(FLEET).expect("bench fleet spelling");

    let mut rows: Vec<Row> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    // (family, slo_ms, deadline 3x goodput fraction) for the baseline.
    let mut slo_rows: Vec<(&str, f64, f64)> = Vec::new();

    harness::section(&format!(
        "overload protection: admission at {OVERLOADS:?}x capacity ({WINDOW_SPEC}, budget \
         {SEARCH_BUDGET}, n={count})"
    ));
    for family in GATED_FAMILIES {
        let sc = scenario_by_id(family).expect("registry family");
        let pool = sc.workload(&gpu, count, SEED);
        let cal_factory: Box<dyn Fn() -> Box<dyn kreorder::exec::ExecutionBackend> + Sync> =
            Box::new(|| {
                Box::new(kreorder::exec::SimulatorBackend::new())
                    as Box<dyn kreorder::exec::ExecutionBackend>
            });
        let capacity: f64 = fleet
            .devices
            .iter()
            .map(|g| fifo_window_capacity_per_s(g, &pool, WINDOW_CAP, cal_factory.as_ref()))
            .sum();

        // SLO calibration: the ungated critical-load p99.
        let critical = run_trace(&fleet, &Trace::poisson(family, count, capacity, SEED), "none");
        let p99_critical = critical.sojourn_stats().p99_ms;
        let slo_ms = (2.0 * p99_critical).max(60.0);
        let bound_spec = format!("bound:{BOUND_Q}");
        let deadline_spec = format!("deadline:{slo_ms:.3}");
        let codel_spec = format!("codel:{:.3}:{:.3}", slo_ms / 4.0, slo_ms);
        println!(
            "  {family:<10} capacity {capacity:.1}/s | critical p99 {p99_critical:.2} ms | \
             SLO {slo_ms:.1} ms"
        );

        let mut goodput_3x_frac = f64::NAN;
        for overload in OVERLOADS {
            let rate = overload * capacity;
            let arrivals = format!("poisson:{rate:.3}:{SEED}");
            let trace = Trace::poisson(family, count, rate, SEED);
            let mut none_p99 = f64::NAN;
            let mut bound_p99 = f64::NAN;
            for admission in [
                "none",
                bound_spec.as_str(),
                deadline_spec.as_str(),
                codel_spec.as_str(),
            ] {
                let r = run_trace(&fleet, &trace, admission);
                // Conservation, the ledger gate: arrivals are either
                // completed or shed (here: rejected), exactly once.
                if r.kernels.len() + r.shed.len() != count {
                    failures.push(format!(
                        "{family}/{overload}x/{admission}: {} completed + {} shed != {count} \
                         arrivals",
                        r.kernels.len(),
                        r.shed.len()
                    ));
                }
                let rejected = r
                    .shed
                    .iter()
                    .filter(|s| matches!(s.cause, ShedCause::Rejected { .. }))
                    .count();
                if rejected != r.shed.len() {
                    failures.push(format!(
                        "{family}/{overload}x/{admission}: {} shed records are not rejections \
                         (no faults ran)",
                        r.shed.len() - rejected
                    ));
                }
                let p99 = r.sojourn_stats().p99_ms;
                let gput = goodput(&r);
                println!(
                    "  {:<10} {:>4.1}x {:<18} admitted-p99 {:>10.2} ms | rejected {:>3} | \
                     goodput {:>7.1}/s | completion {:.4}",
                    family,
                    overload,
                    admission,
                    p99,
                    rejected,
                    gput,
                    r.completion_rate(),
                );
                if admission == "none" {
                    none_p99 = p99;
                    if !r.shed.is_empty() {
                        failures.push(format!(
                            "{family}/{overload}x: admission=none shed {} kernels",
                            r.shed.len()
                        ));
                    }
                } else if admission.starts_with("bound:") {
                    bound_p99 = p99;
                }
                if admission == deadline_spec.as_str() {
                    // The SLO gate: shed keeps the admitted tail inside
                    // the SLO, and the shedding is not a cop-out.
                    if !(p99 <= slo_ms) {
                        failures.push(format!(
                            "{family}/{overload}x: deadline admitted p99 {p99:.2} ms exceeds \
                             the {slo_ms:.2} ms SLO"
                        ));
                    }
                    let floor = GOODPUT_FLOOR_FRAC * capacity;
                    if !(gput >= floor) {
                        failures.push(format!(
                            "{family}/{overload}x: deadline goodput {gput:.1}/s below the \
                             {floor:.1}/s floor (capacity {capacity:.1}/s)"
                        ));
                    }
                    if overload == OVERLOADS[1] {
                        goodput_3x_frac = gput / capacity;
                    }
                }
                rows.push(Row {
                    family,
                    overload,
                    admission: admission.to_string(),
                    arrivals: arrivals.clone(),
                    n: count,
                    completed: r.kernels.len(),
                    rejected,
                    admitted_p99_ms: p99,
                    goodput_per_s: gput,
                    completion_rate: r.completion_rate(),
                    degraded_decisions: r.n_degraded_decisions,
                    span_ms: r.span_ms,
                });
            }
            // The pathology gate: at deep overload an unbounded queue
            // must visibly hurt — otherwise the regime is miscalibrated
            // and every other gate here is vacuous.
            if overload == OVERLOADS[1] && !(none_p99 > bound_p99) {
                failures.push(format!(
                    "{family}/{overload}x: ungated p99 {none_p99:.2} ms does not exceed \
                     bound:{BOUND_Q} admitted p99 {bound_p99:.2} ms — overload miscalibrated"
                ));
            }
        }
        slo_rows.push((family, slo_ms, goodput_3x_frac));
    }

    let gate_ok = failures.is_empty();

    // ---- machine-readable record --------------------------------------
    let mut json = String::from("{\n  \"bench\": \"overload\",\n  \"gpu\": \"gtx580\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!(
        "  \"config\": {{\"fleet\": \"{FLEET}\", \"window\": \"{WINDOW_SPEC}\", \"strategy\": \
         \"search:local:0:{SEARCH_BUDGET}\", \"overloads\": [{}, {}], \"bound_q\": {BOUND_Q}, \
         \"goodput_floor_frac\": {GOODPUT_FLOOR_FRAC}, \"seed\": {SEED}}},\n",
        OVERLOADS[0], OVERLOADS[1]
    ));
    json.push_str(&format!(
        "  \"gates\": {{\"conservation_ok\": {gate_ok}, \"deadline_slo_ok\": {gate_ok}, \
         \"bound_beats_none_ok\": {gate_ok}}},\n"
    ));
    json.push_str("  \"slo\": {\n");
    for (i, (family, slo, frac)) in slo_rows.iter().enumerate() {
        json.push_str(&format!(
            "    \"{family}\": {{\"slo_ms\": {slo:.4}, \"deadline_goodput_frac_3x\": \
             {frac:.4}}}{}\n",
            if i + 1 == slo_rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  },\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"family\": \"{}\", \"overload\": {}, \"admission\": \"{}\", \"arrivals\": \
             \"{}\", \"n\": {},\n     \"completed\": {}, \"rejected\": {}, \
             \"admitted_p99_ms\": {:.6}, \"goodput_per_s\": {:.6},\n     \"completion_rate\": \
             {:.6}, \"degraded_decisions\": {}, \"span_ms\": {:.6}}}{}\n",
            r.family,
            r.overload,
            r.admission,
            r.arrivals,
            r.n,
            r.completed,
            r.rejected,
            r.admitted_p99_ms,
            r.goodput_per_s,
            r.completion_rate,
            r.degraded_decisions,
            r.span_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_overload.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    if !failures.is_empty() {
        eprintln!("\noverload protection gates FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
    println!("\nall overload protection gates passed");
}
