//! Bench + CI gate: **fault tolerance** — health-aware rerouting vs a
//! health-blind router under a deterministic 1-of-4 device crash, plus
//! seeded launch failures absorbed by retry, on the virtual clock.
//!
//! For each gated scenario family the bench:
//!
//! 1. calibrates an arrival rate at ~1.05× the 4-device fleet's summed
//!    FIFO window capacity (the `benches/fleet_routing.rs`
//!    normalization) — mild overload, where losing a device matters;
//! 2. crashes device 1 permanently ~30% into the trace and replays the
//!    **identical** Poisson trace through health-aware `jsq` and a
//!    bench-local *health-blind* JSQ (same score, ignores
//!    `DeviceLoad::health`), so the only difference between the rows is
//!    whether routing steers around the corpse;
//! 3. scores each run by **effective p99**: completed sojourns plus a
//!    censored sojourn of `span - arrival` for every shed kernel — a
//!    router cannot win by stranding kernels and reporting only
//!    survivors;
//! 4. re-runs the same trace with no faults (the degradation
//!    denominator) and with a `launchfail` plan under the default retry
//!    policy (informational: retries absorb, nothing is lost).
//!
//! **Hard gates** (non-zero exit, CI runs `--quick` per push):
//!
//! * conservation — every run accounts `completed + shed == arrivals`;
//! * rerouting pays — health-aware `jsq`'s effective p99 strictly beats
//!   the health-blind router's on every gated crash regime, and sheds
//!   nothing where the blind router strands kernels on the dead device.
//!
//! The `p99_degradation_under_crash` ceiling in `BENCH_baseline.json`'s
//! `faults` section stays warn-only until a real runner calibrates it.
//! Everything is virtual-time: `BENCH_faults.json` is machine-
//! independent, so regressions are scheduling changes, never noise.

#[path = "harness/mod.rs"]
#[allow(dead_code)]
mod harness;

use kreorder::exec::{ExecutionBackend, SimulatorBackend};
use kreorder::fault::{FaultConfig, FaultPlan, RetryPolicy};
use kreorder::fleet::{
    parse_route_policy, simulate_fleet_with_faults, FleetReport, FleetSpec, FleetView, RoutePolicy,
};
use kreorder::gpu::{GpuSpec, KernelProfile};
use kreorder::online::{
    fifo_window_capacity_per_s, parse_window_policy, LatencyStats, OnlineOpts, OnlineReorderer,
    ReplaySource, Trace,
};
use kreorder::workloads::scenario_by_id;

const SEED: u64 = 29;
const WINDOW_CAP: usize = 8;
const WINDOW_SPEC: &str = "linger:8:40";
const SEARCH_BUDGET: u64 = 300;
/// Offered load relative to the healthy fleet's summed FIFO capacity.
const OVERLOAD: f64 = 1.05;
/// Four identical devices; device 1 dies in the crash regimes.
const FLEET: &str = "4";
/// Where in the trace the crash lands (fraction of the nominal span).
const CRASH_FRAC: f64 = 0.3;
/// Regimes the rerouted-vs-blind effective-p99 gate is enforced on.
const GATED_FAMILIES: [&str; 2] = ["skewed", "mixed"];

/// Health-blind join-shortest-queue: the identical score to `jsq` with
/// the health field ignored. This is the no-reroute comparator — after
/// the crash it keeps dealing kernels to the dead device whenever its
/// frozen queue looks shortest.
struct BlindJsq;

impl RoutePolicy for BlindJsq {
    fn name(&self) -> String {
        "blind-jsq".into()
    }
    fn route(&mut self, _kernel: &KernelProfile, fleet: &FleetView<'_>) -> usize {
        let mut best = 0usize;
        let mut best_score = usize::MAX;
        for d in fleet.devices {
            if d.outstanding < best_score {
                best_score = d.outstanding;
                best = d.device;
            }
        }
        best
    }
}

struct Row {
    family: &'static str,
    plan: String,
    route: String,
    arrivals: String,
    n: usize,
    completed: usize,
    shed: usize,
    rerouted: u64,
    launch_failures: u64,
    degraded_decisions: u64,
    p99_ms: f64,
    effective_p99_ms: f64,
    completion_rate: f64,
    span_ms: f64,
}

fn sim_factory() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
}

/// Sojourn p99 with shed kernels censored at end-of-run: a shed kernel
/// contributes `span - arrival` (it waited that long and got nothing).
fn effective_p99(r: &FleetReport) -> f64 {
    let mut xs = r.sojourns_ms();
    xs.extend(r.shed.iter().map(|s| (r.span_ms - s.arrival_ms).max(0.0)));
    LatencyStats::from_samples(&xs).p99_ms
}

fn run_trace(
    fleet: &FleetSpec,
    trace: &Trace,
    route: Box<dyn RoutePolicy>,
    reorderer: &OnlineReorderer,
    faults: &FaultConfig,
) -> FleetReport {
    let gpu = GpuSpec::gtx580();
    let source = Box::new(
        ReplaySource::from_trace(trace, &gpu)
            .expect("registry family")
            .named(trace.family.clone()),
    );
    let factory = sim_factory();
    simulate_fleet_with_faults(
        fleet,
        source,
        route,
        &|| parse_window_policy(WINDOW_SPEC).expect("gate window spelling"),
        reorderer,
        factory.as_ref(),
        &OnlineOpts::default(),
        faults,
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let gpu = GpuSpec::gtx580();
    let count: usize = if quick { 96 } else { 160 };
    let fleet = FleetSpec::parse(FLEET).expect("bench fleet spelling");
    let reorderer = OnlineReorderer::search("local:0", SEARCH_BUDGET).expect("spelling");

    let mut rows: Vec<Row> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    // (family, degradation) pairs for the warn-only baseline ceiling.
    let mut degradations: Vec<(&str, f64)> = Vec::new();

    harness::section(&format!(
        "fault tolerance: 1-of-4 crash, reroute vs blind ({WINDOW_SPEC}, budget \
         {SEARCH_BUDGET}, n={count})"
    ));
    for family in GATED_FAMILIES {
        let sc = scenario_by_id(family).expect("registry family");
        let pool = sc.workload(&gpu, count, SEED);
        let cal_factory = sim_factory();
        let capacity: f64 = fleet
            .devices
            .iter()
            .map(|g| fifo_window_capacity_per_s(g, &pool, WINDOW_CAP, cal_factory.as_ref()))
            .sum();
        let rate = OVERLOAD * capacity;
        let arrivals = format!("poisson:{rate:.3}:{SEED}");
        let trace = Trace::poisson(family, count, rate, SEED);
        // Nominal span of the open-loop schedule; the crash lands partway
        // through so both queues and in-flight batches are live.
        let crash_at = CRASH_FRAC * count as f64 / rate * 1000.0;
        let crash_spec = format!("crash:1@{crash_at:.3}");
        let launchfail_spec = format!("launchfail:0.1:{SEED}");
        let retry = RetryPolicy::new(4, SEED);

        // (label, route, plan spec) — the first two rows carry the gate.
        let regimes: [(&str, Box<dyn RoutePolicy>, &str); 5] = [
            ("jsq", parse_route_policy("jsq").unwrap(), crash_spec.as_str()),
            ("blind-jsq", Box::new(BlindJsq), crash_spec.as_str()),
            ("jsq", parse_route_policy("jsq").unwrap(), "none"),
            ("jsq", parse_route_policy("jsq").unwrap(), launchfail_spec.as_str()),
            (
                "circuit:jsq",
                parse_route_policy("circuit:jsq").unwrap(),
                launchfail_spec.as_str(),
            ),
        ];

        let mut crash_eff: Vec<(String, f64, usize)> = Vec::new();
        let mut nofault_p99 = f64::NAN;
        let mut crash_jsq_eff = f64::NAN;
        for (label, route, plan_spec) in regimes {
            let plan = if plan_spec == "none" {
                FaultPlan::none()
            } else {
                FaultPlan::parse(plan_spec).expect("bench plan spelling")
            };
            let plan_name = plan.name();
            let faults = FaultConfig { plan, retry };
            let r = run_trace(&fleet, &trace, route, &reorderer, &faults);
            if r.kernels.len() + r.shed.len() != count {
                failures.push(format!(
                    "{family}/{label}/{plan_name}: {} completed + {} shed != {count} arrivals",
                    r.kernels.len(),
                    r.shed.len()
                ));
            }
            let eff = effective_p99(&r);
            let p99 = r.sojourn_stats().p99_ms;
            println!(
                "  {:<10} {:<12} plan {:<24} eff-p99 {:>10.2} ms | shed {:>3} | rerouted \
                 {:>3} | launch-fail {:>3} | completion {:.4}",
                family,
                label,
                plan_name,
                eff,
                r.n_shed(),
                r.n_rerouted,
                r.n_launch_failures,
                r.completion_rate(),
            );
            if plan_spec == crash_spec.as_str() {
                crash_eff.push((label.to_string(), eff, r.n_shed()));
                if label == "jsq" {
                    crash_jsq_eff = eff;
                    if r.n_rerouted == 0 {
                        failures.push(format!(
                            "{family}: the crash orphaned nothing — crash_at {crash_at:.1} ms \
                             misses the live window; recalibrate CRASH_FRAC"
                        ));
                    }
                }
            }
            if plan_spec == "none" {
                nofault_p99 = p99;
                if !r.shed.is_empty() || r.n_fault_events != 0 {
                    failures.push(format!(
                        "{family}: the empty plan shed {} kernels / saw {} fault events",
                        r.n_shed(),
                        r.n_fault_events
                    ));
                }
            }
            rows.push(Row {
                family,
                plan: plan_name,
                route: label.to_string(),
                arrivals: arrivals.clone(),
                n: count,
                completed: r.kernels.len(),
                shed: r.n_shed(),
                rerouted: r.n_rerouted,
                launch_failures: r.n_launch_failures,
                degraded_decisions: r.n_degraded_decisions,
                p99_ms: p99,
                effective_p99_ms: eff,
                completion_rate: r.completion_rate(),
                span_ms: r.span_ms,
            });
        }

        // The headline gate: steering around the corpse must strictly
        // beat dealing to it, on the censored (shed-inclusive) p99.
        let blind = crash_eff.iter().find(|(l, _, _)| l == "blind-jsq").unwrap();
        if !(crash_jsq_eff < blind.1) {
            failures.push(format!(
                "{family}: health-aware jsq effective p99 {crash_jsq_eff} ms did not beat \
                 blind-jsq {} ms under {crash_spec}",
                blind.1
            ));
        }
        let degradation = crash_jsq_eff / nofault_p99.max(f64::MIN_POSITIVE);
        degradations.push((family, degradation));
        println!(
            "  {family:<10} crash degradation: {degradation:.3}x (eff-p99 {crash_jsq_eff:.2} \
             ms vs no-fault p99 {nofault_p99:.2} ms)"
        );
    }

    let gate_ok = failures.is_empty();

    // ---- machine-readable record --------------------------------------
    let mut json = String::from("{\n  \"bench\": \"fault_tolerance\",\n  \"gpu\": \"gtx580\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!(
        "  \"config\": {{\"fleet\": \"{FLEET}\", \"window\": \"{WINDOW_SPEC}\", \"strategy\": \
         \"search:local:0:{SEARCH_BUDGET}\", \"overload\": {OVERLOAD}, \"seed\": {SEED}, \
         \"crash_frac\": {CRASH_FRAC}, \"retry\": \"4 attempts, seeded backoff\"}},\n"
    ));
    json.push_str(&format!(
        "  \"gates\": {{\"no_kernel_lost_ok\": {gate_ok}, \
         \"reroute_beats_blind_p99_ok\": {gate_ok}}},\n"
    ));
    json.push_str("  \"degradation\": {\n");
    for (i, (family, d)) in degradations.iter().enumerate() {
        json.push_str(&format!(
            "    \"{family}\": {d:.4}{}\n",
            if i + 1 == degradations.len() { "" } else { "," }
        ));
    }
    json.push_str("  },\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"family\": \"{}\", \"plan\": \"{}\", \"route\": \"{}\", \"arrivals\": \
             \"{}\", \"n\": {},\n     \"completed\": {}, \"shed\": {}, \"rerouted\": {}, \
             \"launch_failures\": {}, \"degraded_decisions\": {},\n     \"p99_ms\": {:.6}, \
             \"effective_p99_ms\": {:.6}, \"completion_rate\": {:.6}, \"span_ms\": {:.6}}}{}\n",
            r.family,
            r.plan,
            r.route,
            r.arrivals,
            r.n,
            r.completed,
            r.shed,
            r.rerouted,
            r.launch_failures,
            r.degraded_decisions,
            r.p99_ms,
            r.effective_p99_ms,
            r.completion_rate,
            r.span_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_faults.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    if !failures.is_empty() {
        eprintln!("\nfault tolerance gates FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
    println!("\nall fault tolerance gates passed");
}
