//! Bench: permutation-sweep throughput (permutations/second) across the
//! three sweep modes — naive per-call `execute`, prepared-flat
//! (`PreparedWorkload::execute_order`), and prefix-checkpointed — for
//! n ∈ {6, 7, 8} synthetic workloads. Writes `BENCH_sweep.json` so the
//! perf trajectory is tracked from this PR onward.
//!
//! `--quick` (the CI smoke step) runs n = 6 only with few samples.

#[path = "harness/mod.rs"]
mod harness;

use kreorder::exec::{ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::perm::{sweep_with_mode, SweepMode};
use kreorder::workloads::synthetic_workload;

fn factorial(n: usize) -> usize {
    (1..=n).product::<usize>().max(1)
}

struct Row {
    n: usize,
    n_perms: usize,
    naive_pps: f64,
    prepared_pps: f64,
    checkpointed_pps: f64,
}

fn main() {
    let gpu = GpuSpec::gtx580();
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick { &[6] } else { &[6, 7, 8] };
    let factory: &(dyn Fn() -> Box<dyn ExecutionBackend> + Sync) =
        &|| Box::new(SimulatorBackend::new());

    harness::section("permutation sweep throughput (fluid simulator)");
    let mut rows = Vec::new();
    for &n in sizes {
        let ks = synthetic_workload(&gpu, n, 7);
        let n_perms = factorial(n);
        let samples = harness::sample_count(if n >= 8 { 4 } else { 8 });
        let modes = [
            ("naive", SweepMode::NaiveExecute),
            ("prepared", SweepMode::PreparedFlat),
            ("checkpointed", SweepMode::Checkpointed),
        ];
        let mut pps = [0.0f64; 3];
        for (mi, (label, mode)) in modes.iter().enumerate() {
            let mean_ms = harness::bench(
                &format!("sweep/{label} n={n} ({n_perms} perms)"),
                1,
                samples,
                || {
                    std::hint::black_box(sweep_with_mode(&gpu, &ks, factory, *mode));
                },
            );
            pps[mi] = n_perms as f64 / (mean_ms / 1e3);
            println!("    -> {:.0} perms/s", pps[mi]);
        }
        println!(
            "    prepared speedup {:.2}x, checkpointed speedup {:.2}x over naive",
            pps[1] / pps[0],
            pps[2] / pps[0]
        );
        rows.push(Row {
            n,
            n_perms,
            naive_pps: pps[0],
            prepared_pps: pps[1],
            checkpointed_pps: pps[2],
        });
    }

    // Machine-readable trajectory record (no serde in the offline env:
    // hand-rolled JSON, readable back via util::Json).
    let mut json = String::from(
        "{\n  \"bench\": \"sweep_throughput\",\n  \"gpu\": \"gtx580\",\n  \"results\": [\n",
    );
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"n\": {}, \"n_perms\": {}, \
             \"perms_per_s\": {{\"naive\": {:.1}, \"prepared_flat\": {:.1}, \
             \"checkpointed\": {:.1}}}, \
             \"speedup_prepared\": {:.3}, \"speedup_checkpointed\": {:.3}}}{}\n",
            r.n,
            r.n_perms,
            r.naive_pps,
            r.prepared_pps,
            r.checkpointed_pps,
            r.prepared_pps / r.naive_pps,
            r.checkpointed_pps / r.naive_pps,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_sweep.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
