//! Bench: the event-driven simulator micro-benchmarks — the inner loop
//! of every permutation sweep, and the primary optimization target of
//! the perf pass (EXPERIMENTS.md §Perf).

#[path = "harness/mod.rs"]
mod harness;

use kreorder::gpu::GpuSpec;
use kreorder::sim::simulate_order;
use kreorder::workloads::{all_experiments, synthetic_workload};

fn main() {
    let gpu = GpuSpec::gtx580();
    let samples = harness::sample_count(40);

    harness::section("simulator: single-order makespan evaluation");
    for e in all_experiments() {
        let order: Vec<usize> = (0..e.kernels.len()).collect();
        let blocks: u32 = e.kernels.iter().map(|k| k.n_blocks).sum();
        let mean = harness::bench(
            &format!("sim/{} ({} blocks)", e.id, blocks),
            5,
            samples,
            || {
                std::hint::black_box(simulate_order(&gpu, &e.kernels, &order));
            },
        );
        println!(
            "    -> {:.2} Msim-blocks/s",
            blocks as f64 / mean / 1e3
        );
    }

    harness::section("simulator: scaling with workload size (synthetic)");
    for n in [4usize, 8, 16, 32, 64] {
        let ks = synthetic_workload(&gpu, n, 7);
        let order: Vec<usize> = (0..n).collect();
        let blocks: u32 = ks.iter().map(|k| k.n_blocks).sum();
        harness::bench(
            &format!("sim/synthetic_{n} kernels ({blocks} blocks)"),
            3,
            samples,
            || {
                std::hint::black_box(simulate_order(&gpu, &ks, &order));
            },
        );
    }
}
