//! Bench + CI gate: **fleet routing** — blind round-robin vs load-aware
//! route policies over multi-device fleets, on the deterministic virtual
//! clock.
//!
//! For each (fleet, scenario family) the bench:
//!
//! 1. calibrates an arrival rate at ~1.05× the fleet's *summed* FIFO
//!    window capacity (the same per-device normalization
//!    `benches/online_latency.rs` uses) — mild overload, where a routing
//!    mistake turns into unbounded queueing on the victim device;
//! 2. replays the **identical** Poisson trace through every registered
//!    route policy with the same per-device window policy and reorderer,
//!    so the only difference between rows is *which device* each kernel
//!    joins;
//! 3. prices each run against the clairvoyant fleet lower bound
//!    (`fleet::fleet_lower_bound` — nominal-profile fluid bound).
//!
//! **Hard gate** (non-zero exit, CI runs `--quick` per push): on the
//! heterogeneous fleet's `skewed` and `small-large` poisson regimes,
//! every non-roundrobin policy's fleet p99 sojourn must not exceed
//! round-robin's. Heterogeneity is where blind dealing loses: round-robin
//! sends a quarter of the load to a quarter-speed device, whose queue
//! then diverges. The homogeneous-fleet rows are informational (there
//! round-robin is already near-balanced and the race is a toss-up). The
//! p99-speedup floors in `BENCH_baseline.json`'s `fleet` section stay
//! warn-only until a real runner calibrates them.
//!
//! Everything is virtual-time: the numbers in `BENCH_fleet.json` are
//! machine-independent (bit-stable f64 arithmetic), so regressions are
//! real scheduling changes, never runner noise.
//!
//! As a side artifact the bench records one round-robin run on the
//! homogeneous D=4 fleet through the `obs` tracing layer and writes both
//! `TRACE_fleet.jsonl` (typed event stream) and `TRACE_fleet.chrome.json`
//! (Chrome/Perfetto timeline); CI uploads both so every push ships an
//! inspectable trace (`kreorder trace inspect TRACE_fleet.jsonl`).

#[path = "harness/mod.rs"]
#[allow(dead_code)]
mod harness;

use kreorder::admission::NoAdmission;
use kreorder::exec::{ExecutionBackend, SimulatorBackend};
use kreorder::fault::FaultConfig;
use kreorder::fleet::{
    fleet_lower_bound, parse_route_policy, simulate_fleet, simulate_fleet_traced, FleetReport,
    FleetSpec,
};
use kreorder::gpu::GpuSpec;
use kreorder::obs::{export, RingSink};
use kreorder::online::{
    fifo_window_capacity_per_s, parse_window_policy, OnlineOpts, OnlineReorderer, ReplaySource,
    Trace,
};
use kreorder::workloads::{scenario_by_id, scenario_ids};

const SEED: u64 = 29;
const WINDOW_CAP: usize = 8;
const WINDOW_SPEC: &str = "linger:8:40";
const SEARCH_BUDGET: u64 = 300;
/// Offered load relative to the fleet's summed FIFO capacity.
const OVERLOAD: f64 = 1.05;
/// Regimes the routed-vs-roundrobin p99 gate is enforced on.
const GATED_FAMILIES: [&str; 2] = ["skewed", "small-large"];
/// Every registered route policy; `roundrobin` is the baseline row.
const ROUTES: [&str; 5] = ["roundrobin", "jsq", "lrw", "p2c:5", "affinity"];
/// (spec, hard-gated): the lopsided fleet carries the gate.
const FLEETS: [(&str, bool); 2] = [("4", false), ("1,1,0.5,0.25", true)];

struct Row {
    fleet: &'static str,
    gated: bool,
    family: &'static str,
    arrivals: String,
    n: usize,
    rate_per_s: f64,
    route: &'static str,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_ms: f64,
    span_ms: f64,
    throughput_per_s: f64,
    imbalance: f64,
    decision_evals: u64,
    lower_bound_ms: f64,
    p99_speedup_vs_roundrobin: f64,
}

fn sim_factory() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
}

fn run_trace(
    fleet: &FleetSpec,
    trace: &Trace,
    route: &str,
    reorderer: &OnlineReorderer,
) -> FleetReport {
    let gpu = GpuSpec::gtx580();
    let source = Box::new(
        ReplaySource::from_trace(trace, &gpu)
            .expect("registry family")
            .named(trace.family.clone()),
    );
    let factory = sim_factory();
    simulate_fleet(
        fleet,
        source,
        parse_route_policy(route).expect("registered route"),
        &|| parse_window_policy(WINDOW_SPEC).expect("gate window spelling"),
        reorderer,
        factory.as_ref(),
        &OnlineOpts::default(),
    )
}

/// CI trace artifact: one traced round-robin run on the homogeneous D=4
/// fleet, exported both as a JSONL event stream and as a Chrome/Perfetto
/// timeline. Deterministic per (seed, config), so the uploaded artifact
/// only changes when scheduling behavior does.
fn emit_trace_artifacts(gpu: &GpuSpec, reorderer: &OnlineReorderer) {
    let fleet = FleetSpec::parse("4").expect("bench fleet spelling");
    let sc = scenario_by_id("skewed").expect("registry family");
    let pool = sc.workload(gpu, 96, SEED);
    let cal_factory = sim_factory();
    let capacity: f64 = fleet
        .devices
        .iter()
        .map(|g| fifo_window_capacity_per_s(g, &pool, WINDOW_CAP, cal_factory.as_ref()))
        .sum();
    let trace = Trace::poisson("skewed", 96, OVERLOAD * capacity, SEED);
    let source = Box::new(
        ReplaySource::from_trace(&trace, gpu)
            .expect("registry family")
            .named(trace.family.clone()),
    );
    let factory = sim_factory();
    let mut ring = RingSink::new(1 << 20);
    let mut admission = NoAdmission;
    let report = simulate_fleet_traced(
        &fleet,
        source,
        parse_route_policy("roundrobin").expect("registered route"),
        &|| parse_window_policy(WINDOW_SPEC).expect("gate window spelling"),
        reorderer,
        factory.as_ref(),
        &OnlineOpts::default(),
        &FaultConfig::default(),
        &mut admission,
        &mut ring,
    );
    let events = ring.snapshot();
    println!(
        "  traced roundrobin fleet=4 skewed: {} kernels, {} events",
        report.kernels.len(),
        events.len()
    );
    for (path, body) in [
        ("TRACE_fleet.jsonl", export::jsonl(&events)),
        ("TRACE_fleet.chrome.json", export::chrome_trace_json(&events)),
    ] {
        match std::fs::write(path, &body) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let gpu = GpuSpec::gtx580();
    let count: usize = if quick { 96 } else { 192 };
    let families: Vec<&'static str> = if quick {
        GATED_FAMILIES.to_vec()
    } else {
        scenario_ids()
    };
    let reorderer = OnlineReorderer::search("local:0", SEARCH_BUDGET).expect("spelling");

    let mut rows: Vec<Row> = Vec::new();
    let mut failures: Vec<String> = Vec::new();

    harness::section(&format!(
        "fleet routing: roundrobin vs load-aware ({WINDOW_SPEC}, budget {SEARCH_BUDGET}, \
         n={count})"
    ));
    for (fleet_spec, gated) in FLEETS {
        let fleet = FleetSpec::parse(fleet_spec).expect("bench fleet spelling");
        for &family in &families {
            let sc = scenario_by_id(family).expect("registry family");
            let pool = sc.workload(&gpu, count, SEED);
            // Fleet capacity = sum of each device's FIFO window capacity
            // on this pool (slow devices contribute proportionally less).
            let cal_factory = sim_factory();
            let capacity: f64 = fleet
                .devices
                .iter()
                .map(|g| fifo_window_capacity_per_s(g, &pool, WINDOW_CAP, cal_factory.as_ref()))
                .sum();
            let rate = OVERLOAD * capacity;
            let arrivals = format!("poisson:{rate:.3}:{SEED}");
            let trace = Trace::poisson(family, count, rate, SEED);
            let lower_bound_ms = fleet_lower_bound(&fleet, &pool);

            let mut rr_p99 = 0.0f64;
            for route in ROUTES {
                let r = run_trace(&fleet, &trace, route, &reorderer);
                assert_eq!(r.kernels.len(), count, "{family}/{route}: lost kernels");
                let s = r.sojourn_stats();
                if route == "roundrobin" {
                    rr_p99 = s.p99_ms;
                }
                let speedup = if route == "roundrobin" || s.p99_ms <= 0.0 {
                    1.0
                } else {
                    rr_p99 / s.p99_ms
                };
                let fleet_label = format!("fleet={fleet_spec}");
                println!(
                    "  {:<14} {:<10} {:<10} p99 {:>10.2} ms ({:>5.2}x vs rr) | imbalance \
                     {:>5.2} | bound {:>8.2} ms",
                    fleet_label,
                    family,
                    route,
                    s.p99_ms,
                    speedup,
                    r.imbalance(),
                    lower_bound_ms,
                );
                if gated
                    && route != "roundrobin"
                    && GATED_FAMILIES.contains(&family)
                    && s.p99_ms > rr_p99 + 1e-9
                {
                    failures.push(format!(
                        "{route} fleet p99 {} ms > roundrobin p99 {rr_p99} ms on \
                         fleet={fleet_spec} {family} ({arrivals})",
                        s.p99_ms
                    ));
                }
                rows.push(Row {
                    fleet: fleet_spec,
                    gated,
                    family,
                    arrivals: arrivals.clone(),
                    n: count,
                    rate_per_s: rate,
                    route,
                    p50_ms: s.p50_ms,
                    p95_ms: s.p95_ms,
                    p99_ms: s.p99_ms,
                    mean_ms: s.mean_ms,
                    span_ms: r.span_ms,
                    throughput_per_s: r.throughput_per_s(),
                    imbalance: r.imbalance(),
                    decision_evals: r.decision_evals,
                    lower_bound_ms,
                    p99_speedup_vs_roundrobin: speedup,
                });
            }
        }
    }

    let gate_ok = failures.is_empty();

    // ---- machine-readable record --------------------------------------
    let mut json = String::from("{\n  \"bench\": \"fleet_routing\",\n  \"gpu\": \"gtx580\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!(
        "  \"config\": {{\"window\": \"{WINDOW_SPEC}\", \"strategy\": \
         \"search:local:0:{SEARCH_BUDGET}\", \"overload\": {OVERLOAD}, \"seed\": {SEED}, \
         \"routes\": [\"roundrobin\", \"jsq\", \"lrw\", \"p2c:5\", \"affinity\"]}},\n"
    ));
    json.push_str(&format!(
        "  \"gates\": {{\"routed_beats_roundrobin_p99_ok\": {gate_ok}}},\n"
    ));
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"fleet\": \"{}\", \"gated\": {}, \"family\": \"{}\", \"arrivals\": \"{}\", \
             \"n\": {}, \"rate_per_s\": {:.4}, \"route\": \"{}\",\n     \"p50_ms\": {:.6}, \
             \"p95_ms\": {:.6}, \"p99_ms\": {:.6}, \"mean_ms\": {:.6}, \"span_ms\": {:.6},\n     \
             \"throughput_per_s\": {:.4}, \"imbalance\": {:.4}, \"decision_evals\": {}, \
             \"fleet_lower_bound_ms\": {:.6},\n     \"p99_speedup_vs_roundrobin\": {:.4}}}{}\n",
            r.fleet,
            r.gated,
            r.family,
            r.arrivals,
            r.n,
            r.rate_per_s,
            r.route,
            r.p50_ms,
            r.p95_ms,
            r.p99_ms,
            r.mean_ms,
            r.span_ms,
            r.throughput_per_s,
            r.imbalance,
            r.decision_evals,
            r.lower_bound_ms,
            r.p99_speedup_vs_roundrobin,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_fleet.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    harness::section("trace artifact (obs tracing layer, roundrobin on fleet=4)");
    emit_trace_artifacts(&gpu, &reorderer);

    if !failures.is_empty() {
        eprintln!("\nfleet routing gates FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
    println!("\nall fleet routing gates passed");
}
