//! Minimal criterion-style bench harness (the offline environment ships
//! no criterion): warmup, fixed sample count, mean/median/stddev/min
//! report lines, and a `--quick` mode for CI.
//!
//! Each bench target is `harness = false` and drives this module from
//! `main()`.

use std::time::Instant;

/// Samples per measurement (halved by `--quick`).
pub fn sample_count(default: usize) -> usize {
    if std::env::args().any(|a| a == "--quick") {
        (default / 4).max(3)
    } else {
        default
    }
}

/// Measure `f` `samples` times after `warmup` unmeasured runs; print a
/// criterion-like summary line and return the per-run mean in ms.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times_ms = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    times_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times_ms.iter().sum::<f64>() / samples as f64;
    let median = times_ms[samples / 2];
    let min = times_ms[0];
    let var = times_ms
        .iter()
        .map(|t| (t - mean) * (t - mean))
        .sum::<f64>()
        / samples.max(2).saturating_sub(1) as f64;
    println!(
        "bench {name:<44} mean {mean:>10.3} ms  median {median:>10.3} ms  min {min:>10.3} ms  stddev {:>8.3} ms  (n={samples})",
        var.sqrt()
    );
    mean
}

/// Pretty section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
