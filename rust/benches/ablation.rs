//! Bench: ablation studies for the design choices DESIGN.md calls out.
//!
//! * A1 — score components (resource leftover / ratio balance / opposing
//!   gate) toggled one at a time.
//! * A2 — intra-round shm-descending sort on/off, and across-round
//!   sequencing policies.
//! * A3 — fluid simulator vs the paper's analytic round model: how well
//!   does round count predict simulated makespan?

#[path = "harness/mod.rs"]
mod harness;

use kreorder::exec::{ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::perm::sweep;
use kreorder::sched::{reorder_with, RoundOrder, ScoreConfig};
use kreorder::sim::rounds::pack_rounds;
use kreorder::workloads::{all_experiments, synthetic_workload};

fn main() {
    let gpu = GpuSpec::gtx580();
    let mut backend: Box<dyn ExecutionBackend> = Box::new(SimulatorBackend::new());

    let configs: Vec<(&str, ScoreConfig)> = vec![
        ("full", ScoreConfig::default()),
        ("paper-strict", ScoreConfig::paper_strict()),
        ("resources-only", ScoreConfig { ratio_balance: false, ..ScoreConfig::default() }),
        ("ratio-only", ScoreConfig { resource_balance: false, ..ScoreConfig::default() }),
        ("no-opposing-gate", ScoreConfig { opposing_gate: false, ..ScoreConfig::default() }),
        ("no-shm-sort", ScoreConfig { shm_sort: false, ..ScoreConfig::default() }),
        ("rounds-shm-desc", ScoreConfig { round_order: RoundOrder::ShmDesc, ..ScoreConfig::default() }),
    ];

    harness::section("A1/A2: score-component ablation (makespan ms, percentile in sweep)");
    print!("{:<14}", "experiment");
    for (name, _) in &configs {
        print!(" | {name:>16}");
    }
    println!();
    for e in all_experiments() {
        let sw = sweep(&gpu, &e.kernels);
        print!("{:<14}", e.id);
        for (_, cfg) in &configs {
            let order = reorder_with(&gpu, &e.kernels, cfg).order;
            let t = backend.execute(&gpu, &e.kernels, &order).makespan_ms;
            print!(" | {:>8.1} {:>5.1}%", t, sw.percentile_rank(t));
        }
        println!();
    }

    harness::section("A1 aggregate over 100 synthetic 8-kernel workloads (mean makespan)");
    for (name, cfg) in &configs {
        let mean: f64 = (0..100)
            .map(|s| {
                let ks = synthetic_workload(&gpu, 8, s);
                let order = reorder_with(&gpu, &ks, cfg).order;
                backend.execute(&gpu, &ks, &order).makespan_ms
            })
            .sum::<f64>()
            / 100.0;
        println!("  {name:<18} {mean:>9.2} ms");
    }

    harness::section("A3: analytic round model vs fluid simulator (rank correlation)");
    // For each experiment, Spearman correlation between analytic round
    // count and simulated makespan across 200 random orders.
    for e in all_experiments() {
        let n = e.kernels.len();
        let mut rng = kreorder::util::SplitMix64::new(42);
        let mut pairs: Vec<(f64, f64)> = Vec::new();
        for _ in 0..200 {
            let mut order: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut order);
            let rounds = pack_rounds(&gpu, &e.kernels, &order).len() as f64;
            let t = backend.execute(&gpu, &e.kernels, &order).makespan_ms;
            pairs.push((rounds, t));
        }
        println!(
            "  {:<14} spearman(rounds, makespan) = {:+.3}",
            e.id,
            spearman(&pairs)
        );
    }

    harness::section("ablation config cost (reorder latency)");
    let ks = synthetic_workload(&gpu, 8, 11);
    let samples = harness::sample_count(50);
    for (name, cfg) in &configs {
        harness::bench(&format!("ablate/{name}"), 10, samples, || {
            std::hint::black_box(reorder_with(&gpu, &ks, cfg));
        });
    }
}

/// Spearman rank correlation of (x, y) pairs.
fn spearman(pairs: &[(f64, f64)]) -> f64 {
    let n = pairs.len();
    let rank = |vals: Vec<f64>| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
        let mut r = vec![0.0; n];
        let mut i = 0;
        while i < n {
            // average ranks over ties
            let mut j = i;
            while j + 1 < n && vals[idx[j + 1]] == vals[idx[i]] {
                j += 1;
            }
            let avg = (i + j) as f64 / 2.0;
            for k in i..=j {
                r[idx[k]] = avg;
            }
            i = j + 1;
        }
        r
    };
    let rx = rank(pairs.iter().map(|p| p.0).collect());
    let ry = rank(pairs.iter().map(|p| p.1).collect());
    let mx = rx.iter().sum::<f64>() / n as f64;
    let my = ry.iter().sum::<f64>() / n as f64;
    let cov: f64 = rx.iter().zip(&ry).map(|(a, b)| (a - mx) * (b - my)).sum();
    let vx: f64 = rx.iter().map(|a| (a - mx) * (a - mx)).sum();
    let vy: f64 = ry.iter().map(|b| (b - my) * (b - my)).sum();
    if vx == 0.0 || vy == 0.0 {
        0.0
    } else {
        cov / (vx * vy).sqrt()
    }
}
