//! Bench: Algorithm 1 latency — the coordinator runs this per batch on
//! the request path, so it must stay well under a millisecond at serving
//! window sizes (target: < 100 µs for 8 kernels).

#[path = "harness/mod.rs"]
mod harness;

use kreorder::gpu::GpuSpec;
use kreorder::sched::{registry, reorder};
use kreorder::workloads::{all_experiments, synthetic_workload};

fn main() {
    let gpu = GpuSpec::gtx580();
    let samples = harness::sample_count(50);

    harness::section("Algorithm 1 on the paper experiments");
    for e in all_experiments() {
        harness::bench(&format!("sched/{}", e.id), 10, samples, || {
            std::hint::black_box(reorder(&gpu, &e.kernels));
        });
    }

    harness::section("Algorithm 1 scaling (synthetic workloads)");
    for n in [4usize, 8, 16, 32, 64, 128] {
        let ks = synthetic_workload(&gpu, n, 3);
        harness::bench(&format!("sched/synthetic_{n}"), 5, samples, || {
            std::hint::black_box(reorder(&gpu, &ks));
        });
    }

    harness::section("registered policies (8 kernels, trait dispatch)");
    let ks = synthetic_workload(&gpu, 8, 5);
    for p in registry::all_policies() {
        harness::bench(&format!("policy/{}", p.name()), 10, samples, || {
            std::hint::black_box(p.order(&gpu, &ks));
        });
    }
}
