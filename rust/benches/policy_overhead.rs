//! Bench: what does the trait redesign cost? Dynamic dispatch
//! (`Box<dyn LaunchPolicy>`) vs direct static dispatch on the concrete
//! policy structs, on the coordinator-relevant batch sizes (8–64
//! kernels). (The pre-0.2 closed-enum `Policy` this bench originally
//! compared against is gone; a monomorphized struct call is the same
//! no-vtable baseline.)
//!
//! The coordinator invokes the policy once per *batch*, so even a large
//! relative overhead would be irrelevant in absolute terms — but the
//! redesign's cost should be measured, not assumed. FIFO isolates the
//! pure dispatch overhead (the policy body is a trivial collect);
//! Algorithm 1 shows how completely real scheduling work amortizes it.

#[path = "harness/mod.rs"]
mod harness;

use kreorder::gpu::GpuSpec;
use kreorder::sched::{registry, Algorithm1Policy, FifoPolicy, LaunchPolicy};
use kreorder::workloads::synthetic_workload;

fn main() {
    let gpu = GpuSpec::gtx580();
    let samples = harness::sample_count(200);

    for n in [8usize, 16, 32, 64] {
        let ks = synthetic_workload(&gpu, n, 42);
        harness::section(&format!("{n}-kernel batch"));

        // --- FIFO: the policy body is trivial, so this pair isolates the
        // static-call vs vtable-call difference.
        let static_fifo = FifoPolicy;
        harness::bench(&format!("static/fifo/{n}"), 20, samples, || {
            std::hint::black_box(static_fifo.order(&gpu, &ks));
        });
        let dyn_fifo: Box<dyn LaunchPolicy> = registry::parse("fifo").unwrap();
        harness::bench(&format!("dyn/fifo/{n}"), 20, samples, || {
            std::hint::black_box(dyn_fifo.order(&gpu, &ks));
        });

        // --- Algorithm 1: real scheduling work (O(n^2) scoring) on both
        // paths; the dispatch difference should vanish in the noise.
        let static_alg = Algorithm1Policy::new();
        harness::bench(&format!("static/algorithm1/{n}"), 5, samples, || {
            std::hint::black_box(static_alg.order(&gpu, &ks));
        });
        let dyn_alg: Box<dyn LaunchPolicy> = registry::parse("algorithm1").unwrap();
        harness::bench(&format!("dyn/algorithm1/{n}"), 5, samples, || {
            std::hint::black_box(dyn_alg.order(&gpu, &ks));
        });

        // --- Registry parse cost (done once per service start, shown for
        // completeness).
        harness::bench(&format!("registry/parse/{n}"), 20, samples, || {
            std::hint::black_box(registry::parse("algorithm1").unwrap());
        });
    }
}
