//! Bench + CI gate: **online tail latency** — FIFO vs reordered windows
//! across arrival regimes, on the deterministic virtual clock.
//!
//! For each scenario family the bench:
//!
//! 1. calibrates an arrival rate at ~1.05× the FIFO service capacity of
//!    that family's trace (capacity measured by chunking the pool into
//!    arrival-order windows and summing simulated makespans) — mild
//!    overload, where queueing amplifies every per-window makespan win;
//! 2. replays the identical Poisson (and, in full mode, bursty) trace
//!    through the same `linger` window policy twice — once launching
//!    windows in FIFO arrival order, once through the budgeted online
//!    reorderer — and records p50/p95/p99 sojourn, sustained kernels/s
//!    and utilization;
//! 3. prices onlineness against the clairvoyant offline oracle
//!    (`online::offline_oracle` over the full trace at t=0).
//!
//! Because both runs share the window policy and trace, window
//! *composition* is identical and the only difference is launch order —
//! the paper's effect, isolated under queueing. **Hard gate** (non-zero
//! exit, CI runs `--quick` per push): the reordered p99 sojourn must
//! not exceed FIFO's on the `skewed` and `small-large` regimes, the two
//! the reordering literature says benefit most. The p99-speedup floors
//! in `BENCH_baseline.json`'s `online` section stay warn-only until a
//! real runner calibrates them.
//!
//! Everything is virtual-time: the numbers in `BENCH_online.json` are
//! machine-independent (bit-stable f64 arithmetic), so regressions are
//! real scheduling changes, never runner noise.

#[path = "harness/mod.rs"]
#[allow(dead_code)]
mod harness;

use kreorder::exec::{ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::online::{
    fifo_window_capacity_per_s, offline_oracle, parse_window_policy, simulate_online, OnlineOpts,
    OnlineReorderer, OnlineReport, ReplaySource, Trace,
};
use kreorder::workloads::{scenario_by_id, scenario_ids};

const SEED: u64 = 23;
const WINDOW_CAP: usize = 8;
const WINDOW_SPEC: &str = "linger:8:40";
const SEARCH_BUDGET: u64 = 300;
/// Offered load relative to measured FIFO capacity: mild overload.
const OVERLOAD: f64 = 1.05;
/// Regimes the reordered-vs-FIFO p99 gate is enforced on.
const GATED_FAMILIES: [&str; 2] = ["skewed", "small-large"];

struct Row {
    family: &'static str,
    arrivals: String,
    n: usize,
    rate_per_s: f64,
    fifo: Summary,
    reordered: Summary,
    oracle_ms: f64,
    oracle_method: String,
}

struct Summary {
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_ms: f64,
    span_ms: f64,
    throughput_per_s: f64,
    utilization: f64,
    decision_evals: u64,
}

fn summarize(r: &OnlineReport) -> Summary {
    let s = r.sojourn_stats();
    Summary {
        p50_ms: s.p50_ms,
        p95_ms: s.p95_ms,
        p99_ms: s.p99_ms,
        mean_ms: s.mean_ms,
        span_ms: r.span_ms,
        throughput_per_s: r.throughput_per_s(),
        utilization: r.utilization(),
        decision_evals: r.decision_evals,
    }
}

fn sim_factory() -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>)
}

fn run_trace(gpu: &GpuSpec, trace: &Trace, reorderer: &OnlineReorderer) -> OnlineReport {
    let source = Box::new(
        ReplaySource::from_trace(trace, gpu)
            .expect("registry family")
            .named(trace.family.clone()),
    );
    let window = parse_window_policy(WINDOW_SPEC).expect("gate window spelling");
    let factory = sim_factory();
    simulate_online(
        gpu,
        source,
        window,
        reorderer,
        factory.as_ref(),
        &OnlineOpts::default(),
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let gpu = GpuSpec::gtx580();
    let count: usize = if quick { 96 } else { 240 };
    let oracle_evals: u64 = if quick { 2_000 } else { 20_000 };
    let families: Vec<&'static str> = if quick {
        GATED_FAMILIES.to_vec()
    } else {
        scenario_ids()
    };
    let reorderer = OnlineReorderer::search("local:0", SEARCH_BUDGET).expect("spelling");
    let fifo = OnlineReorderer::fifo();

    let mut rows: Vec<Row> = Vec::new();
    let mut failures: Vec<String> = Vec::new();

    harness::section(&format!(
        "online sojourn: FIFO vs reordered ({WINDOW_SPEC}, budget {SEARCH_BUDGET}, n={count})"
    ));
    for family in families {
        let sc = scenario_by_id(family).expect("registry family");
        let pool = sc.workload(&gpu, count, SEED);
        let cal_factory = sim_factory();
        let capacity = fifo_window_capacity_per_s(&gpu, &pool, WINDOW_CAP, cal_factory.as_ref());
        let rate = OVERLOAD * capacity;

        let mut regimes: Vec<(String, Trace)> = vec![(
            format!("poisson:{rate:.3}:{SEED}"),
            Trace::poisson(family, count, rate, SEED),
        )];
        if !quick {
            // Trace::bursty's rate parameter is the ON-phase rate and the
            // duty cycle is ~50%, so doubling it keeps the *effective*
            // offered load at the same 1.05x-capacity target as the
            // poisson regime (the label records the ON rate, the
            // rate_per_s column the effective target).
            regimes.push((
                format!("bursty:{:.3}:{SEED}", 2.0 * rate),
                Trace::bursty(family, count, 2.0 * rate, SEED),
            ));
        }

        // The oracle depends only on the pool — one solve serves every
        // arrival regime of this family.
        let factory = sim_factory();
        let oracle = offline_oracle(&gpu, &pool, factory.as_ref(), oracle_evals);

        for (arrivals, trace) in regimes {
            let r_fifo = run_trace(&gpu, &trace, &fifo);
            let r_reord = run_trace(&gpu, &trace, &reorderer);
            assert_eq!(r_fifo.kernels.len(), count, "{family}: lost kernels");
            assert_eq!(r_reord.kernels.len(), count, "{family}: lost kernels");
            let (sf, sr) = (summarize(&r_fifo), summarize(&r_reord));
            println!(
                "  {:<14} {:<22} fifo p99 {:>10.2} ms | reordered p99 {:>10.2} ms \
                 ({:>5.2}x) | oracle {:>9.2} ms ({})",
                family,
                arrivals,
                sf.p99_ms,
                sr.p99_ms,
                sf.p99_ms / sr.p99_ms,
                oracle.makespan_ms,
                oracle.method,
            );
            rows.push(Row {
                family,
                arrivals,
                n: count,
                rate_per_s: rate,
                fifo: sf,
                reordered: sr,
                oracle_ms: oracle.makespan_ms,
                oracle_method: oracle.method.clone(),
            });
        }
    }

    // ---- hard gate: reordering must not lose the tail on the regimes
    // where the paper's effect is largest ------------------------------
    let mut gate_ok = true;
    for row in &rows {
        if !GATED_FAMILIES.contains(&row.family) || !row.arrivals.starts_with("poisson") {
            continue;
        }
        if row.reordered.p99_ms > row.fifo.p99_ms + 1e-9 {
            gate_ok = false;
            failures.push(format!(
                "reordered p99 {} ms > fifo p99 {} ms on {} ({})",
                row.reordered.p99_ms, row.fifo.p99_ms, row.family, row.arrivals
            ));
        }
    }

    // ---- machine-readable record --------------------------------------
    let fmt_summary = |s: &Summary| {
        format!(
            "{{\"p50_ms\": {:.6}, \"p95_ms\": {:.6}, \"p99_ms\": {:.6}, \"mean_ms\": {:.6}, \
             \"span_ms\": {:.6}, \"throughput_per_s\": {:.4}, \"utilization\": {:.4}, \
             \"decision_evals\": {}}}",
            s.p50_ms,
            s.p95_ms,
            s.p99_ms,
            s.mean_ms,
            s.span_ms,
            s.throughput_per_s,
            s.utilization,
            s.decision_evals
        )
    };
    let mut json = String::from("{\n  \"bench\": \"online_latency\",\n  \"gpu\": \"gtx580\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!(
        "  \"config\": {{\"window\": \"{WINDOW_SPEC}\", \"strategy\": \
         \"search:local:0:{SEARCH_BUDGET}\", \"overload\": {OVERLOAD}, \"seed\": {SEED}}},\n"
    ));
    json.push_str(&format!(
        "  \"gates\": {{\"reordered_beats_fifo_p99_ok\": {gate_ok}}},\n"
    ));
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"family\": \"{}\", \"arrivals\": \"{}\", \"n\": {}, \
             \"rate_per_s\": {:.4},\n     \"fifo\": {},\n     \"reordered\": {},\n     \
             \"p99_speedup_vs_fifo\": {:.4},\n     \"oracle\": {{\"makespan_ms\": {:.6}, \
             \"method\": \"{}\", \"gap_vs_online_span\": {:.4}}}}}{}\n",
            r.family,
            r.arrivals,
            r.n,
            r.rate_per_s,
            fmt_summary(&r.fifo),
            fmt_summary(&r.reordered),
            r.fifo.p99_ms / r.reordered.p99_ms,
            r.oracle_ms,
            r.oracle_method,
            r.reordered.span_ms / r.oracle_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_online.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    if !failures.is_empty() {
        eprintln!("\nonline latency gates FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
    println!("\nall online latency gates passed");
}
