//! Bench + CI gate: launch-order **search quality**.
//!
//! Three contracts, all enforced (non-zero exit on violation) in
//! `--quick` mode, which CI runs on every push:
//!
//! 1. **Exactness** — branch-and-bound returns the bit-identical optimal
//!    makespan *and* tie-broken optimal order as the exhaustive
//!    checkpointed sweep, for every scenario family at n ≤ 8 on both
//!    model backends (simulator + analytic).
//! 2. **Anytime quality** — each anytime strategy (`anneal`, `local`) at
//!    a 10 000-evaluation budget lands at or above the 90th percentile
//!    of the full n = 10 permutation distribution on every scenario
//!    family (simulator backend; percentile at histogram resolution).
//! 3. **Cursor identity** — prefix-reuse (cursor) evaluation and full
//!    evaluation of the same seeded strategy produce bit-identical
//!    outcomes (best, order, trajectory) while the throughput section
//!    below records their evals/s ratio.
//! 4. **DAG exactness** — every searcher handed a DAG workload returns
//!    the bit-identical optimum (value and tie-broken order) of the
//!    exhaustive sweep over topological orders only, for every DAG
//!    family at n ≤ 8 on both backends; past the exact cover (n = 12)
//!    the anytime DAG path stays feasible and deterministic per seed.
//!    Per-family linear-extension counts, the n!-shrink factor, the
//!    topological sweep rate and bnb evals land in the `dag` section of
//!    the JSON, alongside n = 11–12 histogram percentiles (p50/p90)
//!    from the constant-memory `sweep_stats_dag` spelling for every
//!    family whose extension count fits the sweep cap.
//!
//! The **anytime throughput** section measures order evaluations per
//! second for three paths: the prefix-reuse cursor, full prepared
//! evaluation (`execute_order`), and naive per-call `execute` (which
//! rebuilds simulator state per order — what any backend without a
//! `prepare` override pays). Expected ratios, hand-computed from the
//! model (documented here because the authoring container has no
//! toolchain to measure): a candidate move at position `p` re-simulates
//! only its `n − p` suffix, and the SA/local move mixes have
//! `E[p] ≈ n/3`, so cursor ÷ prepared-full ≈ `n/(n − n/3)` ≈ **1.5×**
//! (plus checkpoint-restore savings); cursor ÷ naive-execute
//! additionally recovers the per-order state rebuild and is expected
//! **≥ 2×** (PR 2 measured prepared/naive alone near that). CI gates
//! these warn-only against `BENCH_baseline.json` until a real runner
//! calibrates them.
//!
//! Results are written to `BENCH_search.json` (optimality gap, sweep
//! percentile, evals, wall time per strategy × family, plus the
//! `anytime_throughput` records) so the perf/quality trajectory is
//! tracked alongside `BENCH_sweep.json`. The full mode additionally
//! reports n = 12 anytime improvement over the Algorithm 1 warm start,
//! where no sweep reference exists.

// This bench gates pass/fail quality contracts rather than timing loops,
// so it uses only the harness's section headers.
#[path = "harness/mod.rs"]
#[allow(dead_code)]
mod harness;

use kreorder::exec::{AnalyticBackend, ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::perm::{sweep_dag_with, sweep_stats_dag_with, sweep_stats_with, SweepStats};
use kreorder::search::{
    BranchAndBound, LocalSearch, SearchBudget, SearchOutcome, SearchStrategy, SimulatedAnnealing,
};
use kreorder::sched::reorder;
use kreorder::util::SplitMix64;
use kreorder::workloads::{all_dag_scenarios, all_scenarios, scenario_by_id};
use std::time::Instant;

const GATE_BUDGET: u64 = 10_000;
const GATE_PERCENTILE: f64 = 90.0;

struct Row {
    scenario: &'static str,
    backend: &'static str,
    n: usize,
    strategy: String,
    budget: String,
    best_ms: f64,
    gap_pct: f64,
    percentile: f64,
    evals: u64,
    wall_ms: f64,
}

fn factory(backend: &str) -> Box<dyn Fn() -> Box<dyn ExecutionBackend> + Sync> {
    match backend {
        "sim" => Box::new(|| Box::new(SimulatorBackend::new()) as Box<dyn ExecutionBackend>),
        "analytic" => Box::new(|| Box::new(AnalyticBackend::new()) as Box<dyn ExecutionBackend>),
        other => panic!("unknown backend {other}"),
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let gpu = GpuSpec::gtx580();
    let mut failures: Vec<String> = Vec::new();
    let mut rows: Vec<Row> = Vec::new();

    // ---- gate 1: branch-and-bound bitwise exactness vs the sweep ------
    harness::section("branch-and-bound vs exhaustive sweep (bitwise optima)");
    let sizes: &[usize] = if quick { &[6, 8] } else { &[6, 7, 8] };
    let mut bnb_ok = true;
    for sc in all_scenarios() {
        for &n in sizes {
            for backend in ["sim", "analytic"] {
                let ks = sc.workload(&gpu, n, 11);
                let f = factory(backend);
                let stats: SweepStats = sweep_stats_with(&gpu, &ks, f.as_ref(), 4096);
                let out =
                    BranchAndBound::new().search(&gpu, &ks, f.as_ref(), &SearchBudget::unlimited());
                let bits_match = out.best_ms.to_bits() == stats.best_ms.to_bits()
                    && out.best_order == stats.best_order
                    && out.complete;
                println!(
                    "  {:<14} n={n} {:<8} sweep {:>10.4} ms | bnb {:>10.4} ms in {:>6} evals \
                     ({} pruned) {}",
                    sc.id,
                    backend,
                    stats.best_ms,
                    out.best_ms,
                    out.evals,
                    out.pruned_subtrees,
                    if bits_match { "OK" } else { "MISMATCH" }
                );
                if !bits_match {
                    bnb_ok = false;
                    failures.push(format!(
                        "bnb mismatch: {} n={n} {backend}: sweep ({}, {:?}) vs bnb \
                         ({}, {:?}, complete={})",
                        sc.id, stats.best_ms, stats.best_order, out.best_ms, out.best_order,
                        out.complete
                    ));
                }
                rows.push(Row {
                    scenario: sc.id,
                    backend,
                    n,
                    strategy: "bnb".into(),
                    budget: "unlimited".into(),
                    best_ms: out.best_ms,
                    gap_pct: (out.best_ms - stats.best_ms) / stats.best_ms * 100.0,
                    percentile: stats.percentile_rank(out.best_ms),
                    evals: out.evals,
                    wall_ms: out.wall_ms,
                });
            }
        }
    }

    // ---- DAG gates: topological-order search vs the constrained sweep --
    // Every searcher, handed a DAG workload, must land on the bit-identical
    // optimum (value AND tie-broken order) of the exhaustive sweep over
    // topological orders only — on every DAG family, both backends. The
    // anytime strategies route through their exact cover here (extension
    // count within budget), so this also pins that routing.
    harness::section("DAG search vs constrained exhaustive sweep (bitwise optima)");
    let sim = factory("sim");
    struct DagRow {
        scenario: &'static str,
        n: usize,
        extensions: u128,
        shrink: f64,
        topo_perms_per_s: f64,
        bnb_evals: Option<u64>,
        p50_ms: Option<f64>,
        p90_ms: Option<f64>,
    }
    let mut dag_rows: Vec<DagRow> = Vec::new();
    let mut dag_exact_ok = true;
    let dag_sizes: &[usize] = if quick { &[6, 8] } else { &[6, 7, 8] };
    for sc in all_dag_scenarios() {
        for &n in dag_sizes {
            let w = sc.workload(&gpu, n, 11);
            let graph = w.dep_graph().expect("registry DAG families are valid");
            let ext = graph.linear_extension_count().expect("n <= 8 fits the extension DP");
            let factorial: f64 = (1..=n).map(|i| i as f64).product();
            let mut sim_topo_pps = 0.0;
            let mut sim_bnb_evals = 0;
            for backend in ["sim", "analytic"] {
                let f = factory(backend);
                let t0 = Instant::now();
                let sw = sweep_dag_with(&gpu, &w.kernels, &graph, f.as_ref());
                let topo_pps = sw.n_perms as f64 / t0.elapsed().as_secs_f64().max(1e-9);
                let strategies: [Box<dyn SearchStrategy>; 3] = [
                    Box::new(BranchAndBound::new()),
                    Box::new(SimulatedAnnealing::new(7)),
                    Box::new(LocalSearch::new(7)),
                ];
                for s in strategies {
                    let name = s.name();
                    let out = s.search_dag(&gpu, &w, f.as_ref(), &SearchBudget::unlimited());
                    let bits_match = out.best_ms.to_bits() == sw.best_ms.to_bits()
                        && out.best_order == sw.best_order
                        && out.complete;
                    println!(
                        "  {:<10} n={n} {:<8} {:<8} sweep {:>10.4} ms ({:>5} topo orders) | \
                         search {:>10.4} ms in {:>6} evals {}",
                        sc.id,
                        backend,
                        name,
                        sw.best_ms,
                        sw.n_perms,
                        out.best_ms,
                        out.evals,
                        if bits_match { "OK" } else { "MISMATCH" }
                    );
                    if !bits_match {
                        dag_exact_ok = false;
                        failures.push(format!(
                            "DAG mismatch: {} n={n} {backend} {name}: sweep ({}, {:?}) vs \
                             search ({}, {:?}, complete={})",
                            sc.id, sw.best_ms, sw.best_order, out.best_ms, out.best_order,
                            out.complete
                        ));
                    }
                    if backend == "sim" && name == "bnb" {
                        sim_bnb_evals = out.evals;
                    }
                }
                if backend == "sim" {
                    sim_topo_pps = topo_pps;
                }
            }
            dag_rows.push(DagRow {
                scenario: sc.id,
                n,
                extensions: ext,
                shrink: factorial / ext as f64,
                topo_perms_per_s: sim_topo_pps,
                bnb_evals: Some(sim_bnb_evals),
                p50_ms: None,
                p90_ms: None,
            });
        }
    }

    // ---- DAG histogram percentiles at n = 11–12 (constant-memory) -----
    // The streaming `sweep_stats_dag` spelling makes percentile panels
    // affordable past the full-vector wall, but the wall is the
    // linear-extension count, not n (a chain has one order, a fan-out
    // explodes) — guard on the actual count and say so when a family
    // is skipped.
    harness::section("DAG sweep histograms at n=11-12 (sweep_stats_dag percentiles)");
    let stat_cap: u128 = if quick { 200_000 } else { 2_000_000 };
    for sc in all_dag_scenarios() {
        for n in [11usize, 12] {
            let w = sc.workload(&gpu, n, 11);
            let graph = w.dep_graph().expect("registry DAG families are valid");
            let ext = match graph.linear_extension_count() {
                Some(e) if e <= stat_cap => e,
                Some(e) => {
                    println!(
                        "  {:<10} n={n} skipped: {e} topological orders > cap {stat_cap}",
                        sc.id
                    );
                    continue;
                }
                None => {
                    println!(
                        "  {:<10} n={n} skipped: extension count overflows the DP",
                        sc.id
                    );
                    continue;
                }
            };
            let factorial: f64 = (1..=n).map(|i| i as f64).product();
            let t0 = Instant::now();
            let stats = sweep_stats_dag_with(&gpu, &w.kernels, &graph, sim.as_ref(), 4096);
            let pps = stats.n_perms as f64 / t0.elapsed().as_secs_f64().max(1e-9);
            let (p50, p90) = (stats.quantile_ms(0.5), stats.quantile_ms(0.9));
            println!(
                "  {:<10} n={n} {:>8} topo orders  best {:>10.4} ms  p50 {:>10.4}  \
                 p90 {:>10.4}  worst {:>10.4}",
                sc.id, stats.n_perms, stats.best_ms, p50, p90, stats.worst_ms
            );
            dag_rows.push(DagRow {
                scenario: sc.id,
                n,
                extensions: ext,
                shrink: factorial / ext as f64,
                topo_perms_per_s: pps,
                bnb_evals: None,
                p50_ms: Some(p50),
                p90_ms: Some(p90),
            });
        }
    }

    // Past the exact cover (n = 12 > DAG_EXACT_MAX_N), the anytime DAG
    // path proper must stay feasible and deterministic per seed.
    harness::section("anytime DAG feasibility + determinism at n=12 (4k-eval budget)");
    let mut dag_anytime_ok = true;
    for sc in all_dag_scenarios() {
        let w = sc.workload(&gpu, 12, 31);
        let graph = w.dep_graph().expect("registry DAG families are valid");
        let strategies: [Box<dyn SearchStrategy>; 2] = [
            Box::new(SimulatedAnnealing::new(7)),
            Box::new(LocalSearch::new(7)),
        ];
        for s in strategies {
            let budget = SearchBudget::evals(4_000);
            let a = s.search_dag(&gpu, &w, sim.as_ref(), &budget);
            let b = s.search_dag(&gpu, &w, sim.as_ref(), &budget);
            let topo = graph.is_topological(&a.best_order);
            let det = a.best_ms.to_bits() == b.best_ms.to_bits() && a.best_order == b.best_order;
            println!(
                "  {:<10} {:<10} best {:>10.4} ms in {:>5} evals  topological={topo} \
                 deterministic={det}",
                sc.id, a.strategy, a.best_ms, a.evals
            );
            if !topo || !det {
                dag_anytime_ok = false;
                failures.push(format!(
                    "DAG anytime violation: {} {}: topological={topo} deterministic={det}",
                    sc.id, a.strategy
                ));
            }
        }
    }

    // ---- gate 2: anytime quality at the 10k-eval budget, n = 10 -------
    harness::section("anytime strategies vs n=10 sweep distribution (10k-eval budget)");
    let mut anytime_ok = true;
    for sc in all_scenarios() {
        let ks = sc.workload(&gpu, 10, 23);
        let stats = sweep_stats_with(&gpu, &ks, sim.as_ref(), 4096);
        let strategies: [Box<dyn SearchStrategy>; 2] = [
            Box::new(SimulatedAnnealing::new(7)),
            Box::new(LocalSearch::new(7)),
        ];
        for s in strategies {
            let out = s.search(&gpu, &ks, sim.as_ref(), &SearchBudget::evals(GATE_BUDGET));
            let pct = stats.percentile_rank(out.best_ms);
            let gap = (out.best_ms - stats.best_ms) / stats.best_ms * 100.0;
            let pass = pct >= GATE_PERCENTILE;
            println!(
                "  {:<14} {:<10} best {:>10.4} ms  gap {:>6.2}%  percentile {:>6.2}%  {}",
                sc.id,
                out.strategy,
                out.best_ms,
                gap,
                pct,
                if pass { "OK" } else { "BELOW GATE" }
            );
            if !pass {
                anytime_ok = false;
                failures.push(format!(
                    "anytime below gate: {} {} at {} evals: percentile {pct:.2} < \
                     {GATE_PERCENTILE}",
                    sc.id, out.strategy, GATE_BUDGET
                ));
            }
            rows.push(Row {
                scenario: sc.id,
                backend: "sim",
                n: 10,
                strategy: out.strategy.clone(),
                budget: GATE_BUDGET.to_string(),
                best_ms: out.best_ms,
                gap_pct: gap,
                percentile: pct,
                evals: out.evals,
                wall_ms: out.wall_ms,
            });
        }
    }

    // ---- gate 3 + throughput: cursor vs full vs naive evaluation ------
    harness::section("anytime eval throughput (prefix-reuse cursor vs full vs naive)");
    struct ThrRow {
        scenario: &'static str,
        n: usize,
        strategy: String,
        evals: u64,
        cursor_eps: f64,
        full_eps: f64,
        naive_eps: f64,
    }
    let mut thr_rows: Vec<ThrRow> = Vec::new();
    let mut cursor_ok = true;
    let thr_sizes: &[usize] = if quick { &[10] } else { &[10, 12, 16] };
    let thr_budget: u64 = if quick { 4_000 } else { GATE_BUDGET };
    let eps = |out: &SearchOutcome| out.evals as f64 / (out.wall_ms / 1e3).max(1e-9);
    for family in ["uniform", "skewed"] {
        let sc = scenario_by_id(family).expect("registry family");
        for &n in thr_sizes {
            let ks = sc.workload(&gpu, n, 23);
            // Naive reference: per-call `execute` rebuilds all simulator
            // state per order — the price of a backend with no `prepare`
            // override, measured over a fixed set of shuffled orders.
            let naive_eps = {
                let mut backend = SimulatorBackend::new();
                let mut rng = SplitMix64::new(5);
                let mut orders = Vec::new();
                for _ in 0..32 {
                    let mut o: Vec<usize> = (0..n).collect();
                    rng.shuffle(&mut o);
                    orders.push(o);
                }
                let t0 = Instant::now();
                for o in &orders {
                    std::hint::black_box(backend.execute(&gpu, &ks, o).makespan_ms);
                }
                orders.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9)
            };
            let variants: [(Box<dyn SearchStrategy>, Box<dyn SearchStrategy>); 2] = [
                (
                    Box::new(SimulatedAnnealing::new(7)),
                    Box::new(SimulatedAnnealing::new(7).full_evaluation()),
                ),
                (
                    Box::new(LocalSearch::new(7)),
                    Box::new(LocalSearch::new(7).full_evaluation()),
                ),
            ];
            for (fast, full) in variants {
                let budget = SearchBudget::evals(thr_budget);
                let a = fast.search(&gpu, &ks, sim.as_ref(), &budget);
                let b = full.search(&gpu, &ks, sim.as_ref(), &budget);
                // Hard gate: the cursor is a pure speedup — any drift in
                // best/order/trajectory is a correctness bug.
                let same_traj = a.trajectory.len() == b.trajectory.len()
                    && a.trajectory.iter().zip(&b.trajectory).all(|(x, y)| {
                        x.eval == y.eval && x.best_ms.to_bits() == y.best_ms.to_bits()
                    });
                let identical = a.best_ms.to_bits() == b.best_ms.to_bits()
                    && a.best_order == b.best_order
                    && a.evals == b.evals
                    && same_traj;
                if !identical {
                    cursor_ok = false;
                    failures.push(format!(
                        "cursor incumbent drift: {family} n={n} {}: cursor ({}, {:?}) vs full \
                         ({}, {:?})",
                        a.strategy, a.best_ms, a.best_order, b.best_ms, b.best_order
                    ));
                }
                let (ca, cb) = (eps(&a), eps(&b));
                println!(
                    "  {:<10} n={:<3} {:<10} cursor {:>9.0} evals/s | full {:>9.0} | naive \
                     {:>9.0}  ({:.2}x full, {:.2}x naive) {}",
                    family,
                    n,
                    a.strategy,
                    ca,
                    cb,
                    naive_eps,
                    ca / cb,
                    ca / naive_eps,
                    if identical { "OK" } else { "MISMATCH" }
                );
                thr_rows.push(ThrRow {
                    scenario: sc.id,
                    n,
                    strategy: a.strategy.clone(),
                    evals: a.evals,
                    cursor_eps: ca,
                    full_eps: cb,
                    naive_eps,
                });
            }
        }
    }

    // ---- full mode: n = 12, anytime improvement over the warm start ----
    if !quick {
        harness::section("anytime improvement over Algorithm 1 at n=12 (no sweep reference)");
        for sc in all_scenarios() {
            let ks = sc.workload(&gpu, 12, 31);
            let greedy_order = reorder(&gpu, &ks).order;
            let greedy_ms = SimulatorBackend::new()
                .execute(&gpu, &ks, &greedy_order)
                .makespan_ms;
            for s in [
                Box::new(SimulatedAnnealing::new(7)) as Box<dyn SearchStrategy>,
                Box::new(LocalSearch::new(7)),
            ] {
                let out = s.search(&gpu, &ks, sim.as_ref(), &SearchBudget::evals(GATE_BUDGET));
                println!(
                    "  {:<14} {:<10} algorithm1 {:>10.4} ms -> {:>10.4} ms ({:+.2}%)",
                    sc.id,
                    out.strategy,
                    greedy_ms,
                    out.best_ms,
                    (out.best_ms - greedy_ms) / greedy_ms * 100.0
                );
                rows.push(Row {
                    scenario: sc.id,
                    backend: "sim",
                    n: 12,
                    strategy: out.strategy.clone(),
                    budget: GATE_BUDGET.to_string(),
                    best_ms: out.best_ms,
                    gap_pct: (out.best_ms - greedy_ms) / greedy_ms * 100.0,
                    percentile: f64::NAN,
                    evals: out.evals,
                    wall_ms: out.wall_ms,
                });
            }
        }
    }

    // ---- machine-readable trajectory record ---------------------------
    let mut json = String::from("{\n  \"bench\": \"search_quality\",\n  \"gpu\": \"gtx580\",\n");
    json.push_str(&format!(
        "  \"gates\": {{\"bnb_bitwise_ok\": {bnb_ok}, \"anytime_p90_ok\": {anytime_ok}, \
         \"cursor_identical_ok\": {cursor_ok}, \"dag_bitwise_ok\": {dag_exact_ok}, \
         \"dag_anytime_ok\": {dag_anytime_ok}}},\n"
    ));
    json.push_str("  \"dag\": [\n");
    for (i, r) in dag_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"n\": {}, \"extensions\": {}, \
             \"shrink_vs_factorial\": {:.2}, \"topo_sweep_perms_per_s\": {:.1}, \
             \"bnb_evals\": {}, \"p50_ms\": {}, \"p90_ms\": {}}}{}\n",
            r.scenario,
            r.n,
            r.extensions,
            r.shrink,
            r.topo_perms_per_s,
            r.bnb_evals.map_or("null".to_string(), |v| v.to_string()),
            r.p50_ms.map_or("null".to_string(), |v| format!("{v:.4}")),
            r.p90_ms.map_or("null".to_string(), |v| format!("{v:.4}")),
            if i + 1 == dag_rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"anytime_throughput\": [\n");
    for (i, r) in thr_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"n\": {}, \"strategy\": \"{}\", \"evals\": {}, \
             \"evals_per_s\": {{\"cursor\": {:.1}, \"full\": {:.1}, \"naive_execute\": {:.1}}}, \
             \"speedup_vs_full\": {:.3}, \"speedup_vs_naive\": {:.3}}}{}\n",
            r.scenario,
            r.n,
            r.strategy,
            r.evals,
            r.cursor_eps,
            r.full_eps,
            r.naive_eps,
            r.cursor_eps / r.full_eps,
            r.cursor_eps / r.naive_eps,
            if i + 1 == thr_rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"backend\": \"{}\", \"n\": {}, \"strategy\": \"{}\", \
             \"budget\": \"{}\", \"best_ms\": {:.6}, \"gap_pct\": {:.4}, \"percentile\": {}, \
             \"evals\": {}, \"wall_ms\": {:.3}}}{}\n",
            r.scenario,
            r.backend,
            r.n,
            r.strategy,
            r.budget,
            r.best_ms,
            r.gap_pct,
            if r.percentile.is_nan() {
                "null".to_string()
            } else {
                format!("{:.4}", r.percentile)
            },
            r.evals,
            r.wall_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_search.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    if !failures.is_empty() {
        eprintln!("\nsearch quality gates FAILED:");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
    println!("\nall search quality gates passed");
}
