//! End-to-end serving driver — proves all three layers compose.
//!
//! A stream of kernel-launch batches flows through the coordinator:
//! every batch is reordered by Algorithm 1, timed on the simulated
//! GTX580 under both FIFO and the reordered sequence, and **each
//! kernel's real payload** — the Pallas kernels (EP / BlackScholes /
//! Electrostatics / Smith-Waterman) AOT-compiled to HLO by
//! `make artifacts` — is executed on the PJRT CPU client in the
//! reordered order. Python never runs here.
//!
//! Run with: `make artifacts && cargo run --release --example serve [--requests N]`
//!
//! Reports per-batch latency and throughput plus the aggregate simulated
//! speedup of reordering vs arrival order. The run is recorded in
//! EXPERIMENTS.md §End-to-end.

use kreorder::coordinator::{CoordinatorBuilder, LaunchRequest};
use kreorder::gpu::GpuSpec;
use kreorder::metrics::percentile;
use kreorder::profile::ArtifactStore;
use kreorder::util::SplitMix64;
use kreorder::workloads::synthetic_workload;
use std::time::{Duration, Instant};

fn arg(key: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == key)
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n_requests = arg("--requests", 64);
    let window = arg("--window", 8);
    let devices = arg("--devices", 1);
    let seed = arg("--seed", 0) as u64;

    let artifacts = ArtifactStore::default_dir();
    anyhow::ensure!(
        artifacts.join("profiles.json").exists(),
        "artifacts not found at {} — run `make artifacts` first",
        artifacts.display()
    );

    let gpu = GpuSpec::gtx580();
    let coord = CoordinatorBuilder::new()
        .gpu(gpu.clone())
        .policy_named("algorithm1")?
        .pjrt_backend(artifacts)
        .devices(devices)
        .window(window)
        .linger(Duration::from_millis(5))
        .start();

    println!(
        "serving {n_requests} kernel launches (window {window}, devices {devices}, policy algorithm1)…"
    );
    let t0 = Instant::now();
    let mut rng = SplitMix64::new(seed);
    let mut latencies = Vec::with_capacity(n_requests);
    let mut checksums = 0usize;
    let mut submitted = 0u64;
    while (submitted as usize) < n_requests {
        // One "application burst" = a synthetic multi-kernel workload,
        // submitted together and awaited before the next burst arrives
        // (closed-loop client).
        let burst = synthetic_workload(&gpu, window.min(n_requests - submitted as usize), seed + submitted);
        let mut handles = Vec::with_capacity(burst.len());
        for k in burst {
            handles.push(coord.submit(LaunchRequest {
                id: submitted,
                profile: k,
                seed: rng.next_u64(),
            }));
            submitted += 1;
        }
        coord.flush();
        for h in handles {
            let r = h.wait()?;
            latencies.push(r.latency_ms);
            if r.checksum.is_finite() {
                checksums += 1;
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let (reports, stats) = coord.shutdown();

    println!("\nper-batch simulated GTX580 comparison:");
    println!("  batch  dev   n   fifo(ms)  reordered(ms)  speedup");
    for r in &reports {
        println!(
            "  {:>5} {:>4} {:>3} {:>10.2} {:>13.2} {:>8.3}x",
            r.batch_id,
            r.device,
            r.n,
            r.sim_fifo_ms,
            r.sim_policy_ms,
            r.sim_fifo_ms / r.sim_policy_ms
        );
    }

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("\nend-to-end service metrics (real PJRT execution):");
    println!("  requests served      : {} ({} with verified finite output)", stats.n_responses, checksums);
    println!("  wall time            : {:.2} s", wall_s);
    println!("  throughput           : {:.1} kernels/s", stats.n_responses as f64 / wall_s);
    println!("  latency p50 / p95 / max: {:.1} / {:.1} / {:.1} ms",
        percentile(&latencies, 50.0), percentile(&latencies, 95.0), stats.max_latency_ms);
    println!("  simulated reordering speedup vs FIFO: {:.3}x", stats.sim_speedup());
    println!("  failures             : {}", stats.n_failures);
    anyhow::ensure!(stats.n_failures == 0, "some kernel executions failed");
    anyhow::ensure!(checksums == n_requests, "missing finite outputs");
    println!("\nOK — three-layer round trip verified (Pallas→HLO→PJRT under reordered dispatch).");
    Ok(())
}
