//! Quickstart: build a mixed kernel workload, derive a launch order with
//! the paper's Algorithm 1, and compare it against FIFO on the simulated
//! GTX580.
//!
//! Run with: `cargo run --release --example quickstart`

use kreorder::exec::{ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::sched::{registry, reorder};
use kreorder::sim::{self, rounds::pack_rounds};
use kreorder::workloads::{blackscholes, electrostatics, ep, smith_waterman};

fn main() {
    // The paper's experimental platform: an NVIDIA GTX580 (Table 1).
    let gpu = GpuSpec::gtx580();

    // A mixed workload: memory-bound kernels (EP, SW) and compute-bound
    // ones (BS, ES) with clashing shared-memory footprints — enough
    // resource pressure that the launch order decides how many kernels
    // co-execute per round.
    let kernels = vec![
        ep("-a", 16, 16 * 1024),
        ep("-b", 32, 24 * 1024),
        smith_waterman("-a", 16, 192, 40 * 1024),
        smith_waterman("-b", 16, 192, 24 * 1024),
        blackscholes("-a", 32, 256, 0, 140_000.0),
        blackscholes("-b", 16, 512, 0, 140_000.0),
        electrostatics("-a", 32, 128, 0),
        electrostatics("-b", 32, 256, 8 * 1024),
    ];
    sim::validate_workload(&gpu, &kernels).expect("workload must be simulable");

    println!("workload:");
    for (i, k) in kernels.iter().enumerate() {
        let f = k.per_sm_footprint(&gpu);
        println!(
            "  [{i}] {:<10} warps/SM {:>2}  shm/SM {:>6} B  R = {:>5.2} ({})",
            k.name,
            f.warps,
            f.shmem,
            k.ratio,
            if k.memory_bound(&gpu) { "memory-bound" } else { "compute-bound" },
        );
    }

    // Algorithm 1: greedy round construction from the static profiles.
    let schedule = reorder(&gpu, &kernels);
    println!("\nAlgorithm 1 launch order: {:?}", schedule.order);
    for (r, round) in pack_rounds(&gpu, &kernels, &schedule.order).iter().enumerate() {
        let names: Vec<&str> = round.kernels.iter().map(|&i| kernels[i].name.as_str()).collect();
        println!(
            "  execution round {r}: {:?}  (combined inst/byte ratio {:.2}, R_B = {:.2})",
            names, round.combined_ratio, gpu.balanced_ratio
        );
    }

    // Compare every registered policy on the simulator backend — the
    // same trait seams the coordinator and benches dispatch through.
    let mut backend = SimulatorBackend::new();
    println!("\n{} GTX580 makespan per registered policy:", backend.name());
    let mut fifo_ms = 0.0;
    let mut alg_ms = 0.0;
    for policy in registry::all_policies() {
        let order = policy.order(&gpu, &kernels);
        let t = backend.execute(&gpu, &kernels, &order).makespan_ms;
        match policy.name().as_str() {
            "fifo" => fifo_ms = t,
            "algorithm1" => alg_ms = t,
            _ => {}
        }
        println!("  {:<18} {:>8.2} ms", policy.name(), t);
    }
    println!("\nreordering speedup vs FIFO: {:.3}x", fifo_ms / alg_ms);
}
