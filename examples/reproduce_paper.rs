//! End-to-end reproduction of the paper's evaluation: regenerates every
//! row of Table 3 and both panels of Fig. 1, writing the CSV artifacts
//! alongside a markdown report.
//!
//! Run with: `cargo run --release --example reproduce_paper [out_dir]`
//!
//! Outputs (in `out_dir`, default `.`):
//!   * `table3.md` / `table3.csv` — the six-experiment comparison table
//!   * `fig1_ranking.csv`         — sorted makespans of all 40 320
//!     EpBsEsSw-8 launch orders (Fig. 1 top panel)
//!   * `fig1_distribution.csv`    — histogram of the same (bottom panel)

use kreorder::exec::{ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::metrics::{ExperimentRow, Histogram, Table3};
use kreorder::perm::sweep;
use kreorder::sched::{registry, LaunchPolicy};
use kreorder::workloads::all_experiments;

/// Paper values for side-by-side comparison (Table 3 of the paper):
/// (name, optimal, worst, algorithm, percentile, speedup, deviation%).
const PAPER: [(&str, f64, f64, f64, f64, f64, f64); 6] = [
    ("EP-6-shm", 140.46, 249.15, 146.38, 91.5, 1.702, 4.21),
    ("EP-6-grid", 123.39, 156.03, 123.45, 96.3, 1.264, 0.049),
    ("BS-6-blk", 699.29, 1699.04, 702.29, 96.5, 2.419, 0.43),
    ("EpBs-6", 100.03, 167.47, 100.20, 96.1, 1.671, 0.17),
    ("EpBs-6-shm", 251.90, 311.79, 251.95, 99.4, 1.238, 0.02),
    ("EpBsEsSw-8", 109.21, 597.43, 115.23, 94.8, 5.185, 5.51),
];

fn main() {
    let out_dir = std::env::args().nth(1).unwrap_or_else(|| ".".into());
    std::fs::create_dir_all(&out_dir).expect("create out_dir");
    let gpu = GpuSpec::gtx580();
    let mut table = Table3::default();
    let policy: Box<dyn LaunchPolicy> = registry::parse("algorithm1").unwrap();
    let mut backend: Box<dyn ExecutionBackend> = Box::new(SimulatorBackend::new());

    println!("== Table 3 ==");
    for e in all_experiments() {
        let n_perms: usize = (1..=e.kernels.len()).product();
        eprintln!("  {} ({} permutations)…", e.name, n_perms);
        let sw = sweep(&gpu, &e.kernels);
        let order = policy.order(&gpu, &e.kernels);
        let t_alg = backend.execute(&gpu, &e.kernels, &order).makespan_ms;
        let row = ExperimentRow {
            name: e.name.to_string(),
            optimal_ms: sw.best_ms,
            worst_ms: sw.worst_ms,
            algorithm_ms: t_alg,
            percentile: sw.percentile_rank(t_alg),
            n_perms: sw.n_perms,
        };
        let paper = PAPER.iter().find(|p| p.0 == e.name).unwrap();
        println!(
            "  {:<12} ours: pct {:>5.1}% spdup {:>5.3} dev {:>6.2}%   paper: pct {:>5.1}% spdup {:>5.3} dev {:>5.2}%",
            e.name,
            row.percentile,
            row.speedup_over_worst(),
            row.deviation_from_optimal_pct(),
            paper.4,
            paper.5,
            paper.6,
        );
        table.push(row);

        // Fig. 1 comes from the EpBsEsSw-8 sweep we just ran.
        if e.id == "epbsessw-8" {
            let sorted = sw.sorted_times();
            let mut ranking = String::from("rank,makespan_ms\n");
            for (i, t) in sorted.iter().enumerate() {
                ranking.push_str(&format!("{},{:.6}\n", i + 1, t));
            }
            std::fs::write(format!("{out_dir}/fig1_ranking.csv"), ranking).unwrap();
            let hist = Histogram::build(&sw.times, 60);
            std::fs::write(format!("{out_dir}/fig1_distribution.csv"), hist.to_csv()).unwrap();

            let median = sw.median_ms();
            println!("\n== Fig. 1 (EpBsEsSw-8) ==");
            println!("  permutations: {}", sw.n_perms);
            println!("  algorithm percentile: {:.1}%", sw.percentile_rank(t_alg));
            println!(
                "  gain over median random choice: {:.1}% (paper: 16.1%)",
                (median - t_alg) / median * 100.0
            );
            println!(
                "  speedup over worst: {:.3}x (paper: 5.185x)",
                sw.worst_ms / t_alg
            );
        }
    }

    std::fs::write(format!("{out_dir}/table3.md"), table.to_markdown()).unwrap();
    std::fs::write(format!("{out_dir}/table3.csv"), table.to_csv()).unwrap();
    println!("\nwrote {out_dir}/table3.md, table3.csv, fig1_ranking.csv, fig1_distribution.csv");
    println!("\n{}", table.to_markdown());
}
