//! Ablation study (DESIGN.md A1/A2): which parts of Algorithm 1's score
//! actually matter, on every paper experiment plus a synthetic pool.
//!
//! Varies one score component at a time and reports the simulated
//! makespan and its percentile in the full permutation space.
//!
//! Run with: `cargo run --release --example ablation`

use kreorder::exec::{ExecutionBackend, SimulatorBackend};
use kreorder::gpu::GpuSpec;
use kreorder::perm::sweep;
use kreorder::sched::{reorder_with, RoundOrder, ScoreConfig};
use kreorder::workloads::{all_experiments, synthetic_workload};

fn configs() -> Vec<(&'static str, ScoreConfig)> {
    vec![
        ("full (default)", ScoreConfig::default()),
        ("paper-strict", ScoreConfig::paper_strict()),
        (
            "resources-only",
            ScoreConfig {
                ratio_balance: false,
                ..ScoreConfig::default()
            },
        ),
        (
            "ratio-only",
            ScoreConfig {
                resource_balance: false,
                ..ScoreConfig::default()
            },
        ),
        (
            "no-opposing-gate",
            ScoreConfig {
                opposing_gate: false,
                ..ScoreConfig::default()
            },
        ),
        (
            "no-shm-sort",
            ScoreConfig {
                shm_sort: false,
                ..ScoreConfig::default()
            },
        ),
        (
            "rounds-shm-desc",
            ScoreConfig {
                round_order: RoundOrder::ShmDesc,
                ..ScoreConfig::default()
            },
        ),
    ]
}

fn main() {
    let gpu = GpuSpec::gtx580();
    let cfgs = configs();
    let mut backend = SimulatorBackend::new();

    // Header.
    print!("| Workload |");
    for (name, _) in &cfgs {
        print!(" {name} |");
    }
    println!();
    print!("|---|");
    for _ in &cfgs {
        print!("---|");
    }
    println!();

    // Paper experiments: report makespan + percentile (sweep once each).
    for e in all_experiments() {
        let sw = sweep(&gpu, &e.kernels);
        print!("| {} |", e.name);
        for (_, cfg) in &cfgs {
            let order = reorder_with(&gpu, &e.kernels, cfg).order;
            let t = backend.execute(&gpu, &e.kernels, &order).makespan_ms;
            print!(" {:.1} ({:.0}%) |", t, sw.percentile_rank(t));
        }
        println!();
    }

    // Synthetic pool: mean makespan over many seeds (no sweep — 8! each
    // would be slow across 50 seeds; makespan comparison suffices).
    let seeds: Vec<u64> = (0..50).collect();
    print!("| synthetic-8 (mean of {} seeds) |", seeds.len());
    for (_, cfg) in &cfgs {
        let mean: f64 = seeds
            .iter()
            .map(|&s| {
                let ks = synthetic_workload(&gpu, 8, s);
                let order = reorder_with(&gpu, &ks, cfg).order;
                backend.execute(&gpu, &ks, &order).makespan_ms
            })
            .sum::<f64>()
            / seeds.len() as f64;
        print!(" {mean:.1} |");
    }
    println!();
}
